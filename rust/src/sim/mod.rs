//! Cycle-accurate functional simulator for the streaming CGRA.
//!
//! Executes a verified [`Mapping`] on a stream of input vectors, modelling
//! the modulo-pipelined machine cycle by cycle: iteration `i`'s node `v`
//! executes at cycle `i·II + t(v)`. The simulator is a *bug detector* for
//! the whole mapping stack — it dynamically re-checks what the binder
//! promised:
//!
//! * one op per PE per cycle;
//! * bus exclusiveness per cycle (reads on their column buses, write-outs
//!   on their row buses, internal transfers on their claimed row/column
//!   buses — broadcast of one value allowed);
//! * GRF write ports per cycle;
//! * value/iteration consistency: every operand fetched belongs to the
//!   consumer's iteration (catches pipeline hazards that static checks
//!   miss).
//!
//! Register pressure (LRF per PE, GRF liveness) is analyzed statically and
//! checked against capacities.
//!
//! ## Fused bundles and batched request windows
//!
//! The core loop is fusion-aware *and* batch-aware:
//! [`simulate_fused_batch`] runs a multi-block mapping (see
//! `crate::mapper::map_unit`) over a **request window** — per member, a
//! list of request segments run back to back in one lockstep pass, each
//! segment with its own weights; members short of the window's lockstep
//! length (and members absent from the window) stream zeros for the
//! remainder. Every node's channel/kernel indices and weights resolve
//! through the mapping's [`BlockTags`] provenance, and outputs plus a
//! proportional share of the pass's cycles come back **per segment** — so
//! the serving layer charges a window of W member requests for ONE
//! configuration residency instead of W whole-bundle passes.
//! [`simulate_fused`] is the one-segment-per-member wrapper (equal-length
//! streams, per-block outputs and COPs/MCIDs) and [`simulate`] the
//! single-block wrapper over the same core.
//!
//! ## Three tiers, one semantics
//!
//! This interpreter is the **reference semantics** — and, per the
//! crate's hot-path-rewrite discipline, the differential oracle for two
//! faster tiers that replay the same windows: the scalar compiled plan
//! in [`plan`] ([`ExecPlan`] pre-resolves every per-cycle decision once
//! at mapping time, [`execute_plan_batch`] replays a window as tight
//! inner loops) and the lane-vectorized sweep in [`lanes`]
//! ([`execute_plan_lanes`] evaluates a whole chunk of lockstep
//! iterations per pass over the op array). All three are held
//! bit-identical on every field of [`BatchSimResult`] by the three-way
//! oracle in `tests/sim_equivalence.rs`. The serving tier picks the
//! backend via `[coordinator] sim_backend` and the lane width via
//! `[coordinator] sim_lanes`.

use std::collections::HashMap;

use crate::arch::StreamingCgra;
use crate::bind::{BusAt, Mapping, Placement, Route};
use crate::dfg::fuse::BlockTags;
use crate::dfg::{EdgeKind, NodeId, NodeKind};
use crate::error::{Error, Result};
use crate::mapper::{per_block_stats, BlockStats};
use crate::sparse::SparseBlock;

pub mod lanes;
pub mod plan;

pub use lanes::{execute_plan_lanes, execute_plan_lanes_with, ExecScratch};
pub use plan::{execute_plan_batch, execute_plan_batch_with, ExecPlan};

/// Result of simulating a mapping over an input stream.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Output vectors, one per iteration (kernel-indexed).
    pub outputs: Vec<Vec<f32>>,
    /// Total cycles from first read to last write-back.
    pub cycles: u64,
    /// Iterations executed.
    pub iterations: usize,
    /// Busy cycles per PE (row-major), for utilization reporting.
    pub pe_busy: Vec<u64>,
    /// Peak LRF registers used on any PE.
    pub lrf_peak: usize,
    /// Peak live GRF values.
    pub grf_peak: usize,
}

impl SimResult {
    /// Average PE utilization over the run; `0.0` for a zero-cycle run
    /// (nothing executed, so nothing was busy — never `NaN`).
    pub fn pe_utilization(&self) -> f64 {
        if self.cycles == 0 || self.pe_busy.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.pe_busy.iter().sum();
        busy as f64 / (self.pe_busy.len() as f64 * self.cycles as f64)
    }

    /// Throughput in iterations per cycle (→ `1/II` in steady state);
    /// `0.0` for a zero-cycle run — never `NaN`.
    pub fn throughput(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.iterations as f64 / self.cycles as f64
    }
}

/// One member block's share of a fused simulation.
#[derive(Clone, Debug)]
pub struct BlockSim {
    /// Output vectors, one per iteration (member-kernel-indexed).
    pub outputs: Vec<Vec<f32>>,
    /// Caching operations the member's schedule carries.
    pub cops: usize,
    /// Multi-cycle internal dependencies the member's schedule carries.
    pub mcids: usize,
}

/// Result of simulating a fused mapping: per-member outputs and schedule
/// statistics plus the fabric-global counters.
#[derive(Clone, Debug)]
pub struct FusedSimResult {
    /// One entry per member block, in bundle order.
    pub per_block: Vec<BlockSim>,
    pub cycles: u64,
    pub iterations: usize,
    pub pe_busy: Vec<u64>,
    pub lrf_peak: usize,
    pub grf_peak: usize,
}

impl FusedSimResult {
    /// Average PE utilization over the run — the quantity fusion exists to
    /// raise. `0.0` for a zero-cycle run — never `NaN`.
    pub fn pe_utilization(&self) -> f64 {
        if self.cycles == 0 || self.pe_busy.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.pe_busy.iter().sum();
        busy as f64 / (self.pe_busy.len() as f64 * self.cycles as f64)
    }

    /// Throughput in (fused) iterations per cycle (→ `1/II` in steady
    /// state — one fused iteration advances *every* member by one).
    /// `0.0` for a zero-cycle run — never `NaN`.
    pub fn throughput(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.iterations as f64 / self.cycles as f64
    }
}

/// One request's slice of a member's batched stream: the serving layer
/// concatenates concurrent requests for one member into back-to-back
/// segments of a single lockstep pass (fused request batching).
#[derive(Clone, Copy, Debug)]
pub struct MemberSegment<'a> {
    /// The block carrying this segment's weights. Must share the member's
    /// mask structure (same [`SparseBlock::mask_fingerprint`] — exactly
    /// what the serving layer routes by).
    pub block: &'a SparseBlock,
    /// Input vectors, one per iteration, each of length `block.c`.
    pub xs: &'a [Vec<f32>],
}

/// One segment's share of a batched fused pass.
#[derive(Clone, Debug)]
pub struct SegmentSim {
    /// Output vectors for the segment's own iterations
    /// (member-kernel-indexed).
    pub outputs: Vec<Vec<f32>>,
    /// Cycles attributed to this segment: the pass total split
    /// proportionally to segment iteration counts, rounded by cumulative
    /// prefix so the shares sum *exactly* to the pass total.
    pub cycles: u64,
}

/// One member block's share of a batched fused pass.
#[derive(Clone, Debug)]
pub struct MemberBatchSim {
    /// One entry per segment, in the order given to
    /// [`simulate_fused_batch`].
    pub segments: Vec<SegmentSim>,
    /// Caching operations the member's schedule carries.
    pub cops: usize,
    /// Multi-cycle internal dependencies the member's schedule carries.
    pub mcids: usize,
}

/// Result of a batched fused pass: per-member, per-segment outputs plus
/// the fabric-global counters.
#[derive(Clone, Debug)]
pub struct BatchSimResult {
    /// One entry per member block, in bundle order.
    pub per_member: Vec<MemberBatchSim>,
    /// Cycles of the single lockstep pass — what a serving window pays
    /// once, however many requests it carries.
    pub cycles: u64,
    /// Lockstep iteration count: the maximum member total (shorter and
    /// absent members pad with zero-input iterations).
    pub iterations: usize,
    pub pe_busy: Vec<u64>,
    pub lrf_peak: usize,
    pub grf_peak: usize,
}

/// Resolved view of one member's batched stream: request segments run back
/// to back; iterations past the member total are lockstep padding.
struct MemberStream<'a> {
    segments: &'a [MemberSegment<'a>],
    /// Iteration start of each segment plus a total-length sentinel.
    starts: Vec<usize>,
    /// Weight source for padded iterations (their values feed only padded
    /// outputs, which are discarded).
    fallback: &'a SparseBlock,
}

impl<'a> MemberStream<'a> {
    fn new(segments: &'a [MemberSegment<'a>], fallback: &'a SparseBlock) -> Self {
        let mut starts = Vec::with_capacity(segments.len() + 1);
        let mut acc = 0usize;
        starts.push(0);
        for seg in segments {
            acc += seg.xs.len();
            starts.push(acc);
        }
        MemberStream { segments, starts, fallback }
    }

    /// Total real (non-padded) iterations this member runs.
    fn total(&self) -> usize {
        *self.starts.last().expect("sentinel")
    }

    /// `(segment, local iteration)` covering lockstep iteration `iter`;
    /// `None` for padded iterations.
    fn locate(&self, iter: usize) -> Option<(usize, usize)> {
        if iter >= self.total() {
            return None;
        }
        // First start strictly past `iter`, minus one — empty segments
        // (start == next start) are skipped by construction.
        let seg = self.starts.partition_point(|&st| st <= iter) - 1;
        Some((seg, iter - self.starts[seg]))
    }

    fn input(&self, iter: usize, ch: usize) -> f32 {
        self.input_at(self.locate(iter), ch)
    }

    fn weight(&self, iter: usize, ch: usize, kr: usize) -> f32 {
        self.weight_at(self.locate(iter), ch, kr)
    }

    /// [`Self::input`] against a precomputed [`Self::locate`] result —
    /// the compiled backend resolves each member's location once per
    /// iteration instead of once per node.
    fn input_at(&self, loc: Option<(usize, usize)>, ch: usize) -> f32 {
        match loc {
            Some((seg, local)) => self.segments[seg].xs[local][ch],
            None => 0.0,
        }
    }

    /// [`Self::weight`] against a precomputed [`Self::locate`] result.
    fn weight_at(&self, loc: Option<(usize, usize)>, ch: usize, kr: usize) -> f32 {
        match loc {
            Some((seg, _)) => self.segments[seg].block.weight(ch, kr),
            None => self.fallback.weight(ch, kr),
        }
    }

    /// The block a whole lane chunk reads weights from when every lane
    /// sits in segment `seg` (or, for `None`, in padding) — the lane
    /// backend's broadcast fast path, resolving to exactly what
    /// [`Self::weight_at`] would return lane by lane.
    fn weight_source(&self, seg: Option<usize>) -> &SparseBlock {
        match seg {
            Some(s) => self.segments[s].block,
            None => self.fallback,
        }
    }
}

/// Validate a batched window against the member roster and resolve each
/// member's segment list into a [`MemberStream`]. Shared by the scalar
/// interpreter and the compiled-plan backend so both reject malformed
/// windows with identical errors.
fn build_member_streams<'a>(
    members: usize,
    blocks: &[&'a SparseBlock],
    batches: &'a [Vec<MemberSegment<'a>>],
) -> Result<Vec<MemberStream<'a>>> {
    if blocks.len() != members || batches.len() != members {
        return Err(Error::Workload(format!(
            "batched fused simulation of {members} members got {} blocks and {} segment lists",
            blocks.len(),
            batches.len()
        )));
    }
    let mut streams = Vec::with_capacity(blocks.len());
    for (bi, (&b, segs)) in blocks.iter().zip(batches).enumerate() {
        // The roster side of each check is a per-member constant —
        // resolved once here, not once per segment (a member often
        // repeats across a window's segments, one per riding request).
        let fp = b.mask_fingerprint();
        let c = b.c;
        for seg in segs {
            if seg.block.mask_fingerprint() != fp {
                return Err(Error::Workload(format!(
                    "member {bi} ('{}') segment block '{}' has a different mask structure",
                    b.name, seg.block.name
                )));
            }
            if let Some(bad) = seg.xs.iter().find(|x| x.len() != c) {
                return Err(Error::Workload(format!(
                    "member {bi} ('{}') input vector of length {} for {} channels",
                    b.name,
                    bad.len(),
                    c
                )));
            }
        }
        streams.push(MemberStream::new(segs, b));
    }
    Ok(streams)
}

/// Split one lockstep pass's total across segments proportionally to
/// iteration counts (flat member-major order, cumulative-prefix rounding:
/// shares sum *exactly* to the total) and package per-member results.
/// Shared by both simulation backends so attribution rounding can never
/// drift between them.
fn attribute_segments(
    total_cycles: u64,
    outputs: Vec<Vec<Vec<Vec<f32>>>>,
    stats: Vec<BlockStats>,
    total_req_iters: u64,
) -> Vec<MemberBatchSim> {
    let mut acc: u64 = 0;
    let mut first_segment = true;
    let mut per_member = Vec::with_capacity(outputs.len());
    for (segs, st) in outputs.into_iter().zip(stats) {
        let mut segments = Vec::with_capacity(segs.len());
        for outs in segs {
            let m = outs.len() as u64;
            let cycles = if total_req_iters == 0 {
                // Degenerate all-empty window: the pass still pays the
                // makespan once — charge it to the first segment.
                if first_segment {
                    total_cycles
                } else {
                    0
                }
            } else {
                total_cycles * (acc + m) / total_req_iters
                    - total_cycles * acc / total_req_iters
            };
            first_segment = false;
            acc += m;
            segments.push(SegmentSim { outputs: outs, cycles });
        }
        per_member.push(MemberBatchSim { segments, cops: st.cops, mcids: st.mcids });
    }
    per_member
}

/// Simulate `mapping` over `xs` (one input vector per iteration — each of
/// length `block.c`, indexed by channel). Single-block wrapper over
/// [`simulate_fused`].
pub fn simulate(
    mapping: &Mapping,
    block: &SparseBlock,
    cgra: &StreamingCgra,
    xs: &[Vec<f32>],
) -> Result<SimResult> {
    let tags = BlockTags::single(mapping.s.g.len());
    let res = simulate_fused(mapping, &tags, &[block], cgra, &[xs])?;
    let outputs = res
        .per_block
        .into_iter()
        .next()
        .map(|b| b.outputs)
        .unwrap_or_default();
    Ok(SimResult {
        outputs,
        cycles: res.cycles,
        iterations: res.iterations,
        pe_busy: res.pe_busy,
        lrf_peak: res.lrf_peak,
        grf_peak: res.grf_peak,
    })
}

/// Simulate a (possibly fused) mapping: `blocks` and `xs` carry one entry
/// per member in bundle order, `tags` is the mapping's node → member
/// provenance, and every member's stream must run the same number of
/// iterations (the fabric advances all members in lockstep). Thin wrapper
/// over [`simulate_fused_batch`] with one segment per member.
pub fn simulate_fused(
    mapping: &Mapping,
    tags: &BlockTags,
    blocks: &[&SparseBlock],
    cgra: &StreamingCgra,
    xs: &[&[Vec<f32>]],
) -> Result<FusedSimResult> {
    if blocks.len() != tags.members() || xs.len() != tags.members() {
        return Err(Error::Workload(format!(
            "fused simulation of {} members got {} blocks and {} streams",
            tags.members(),
            blocks.len(),
            xs.len()
        )));
    }
    let n_iters = xs.first().map_or(0, |x| x.len());
    for (bi, stream) in xs.iter().enumerate() {
        if stream.len() != n_iters {
            return Err(Error::Workload(format!(
                "member {bi} stream runs {} iterations, member 0 runs {n_iters}",
                stream.len()
            )));
        }
    }
    let batches: Vec<Vec<MemberSegment<'_>>> = blocks
        .iter()
        .zip(xs)
        .map(|(&block, &stream)| vec![MemberSegment { block, xs: stream }])
        .collect();
    let res = simulate_fused_batch(mapping, tags, blocks, cgra, &batches)?;
    let per_block = res
        .per_member
        .into_iter()
        .map(|m| {
            let outputs = m
                .segments
                .into_iter()
                .next()
                .map(|seg| seg.outputs)
                .unwrap_or_default();
            BlockSim { outputs, cops: m.cops, mcids: m.mcids }
        })
        .collect();
    Ok(FusedSimResult {
        per_block,
        cycles: res.cycles,
        iterations: res.iterations,
        pe_busy: res.pe_busy,
        lrf_peak: res.lrf_peak,
        grf_peak: res.grf_peak,
    })
}

/// Simulate a fused mapping over a **batched request window**: one
/// lockstep pass serving several requests per member. `batches[bi]` holds
/// member `bi`'s segments (one per request, run back to back, each with
/// its own weights); a member whose total falls short of the window's
/// lockstep length — and any member with no segments at all — streams
/// zeros for the remainder, and its padded outputs are discarded. Each
/// iteration's values depend only on that iteration's inputs and the
/// segment's weights, so every segment's outputs are bit-identical to a
/// dedicated whole-bundle pass carrying just that request.
pub fn simulate_fused_batch(
    mapping: &Mapping,
    tags: &BlockTags,
    blocks: &[&SparseBlock],
    cgra: &StreamingCgra,
    batches: &[Vec<MemberSegment<'_>>],
) -> Result<BatchSimResult> {
    let s = &mapping.s;
    let g = &s.g;
    if tags.len() != g.len() {
        return Err(Error::Workload(format!(
            "block tags cover {} nodes but the mapping has {}",
            tags.len(),
            g.len()
        )));
    }
    let streams = build_member_streams(tags.members(), blocks, batches)?;
    let n_iters = streams.iter().map(MemberStream::total).max().unwrap_or(0);
    let ii = s.ii as u64;
    let makespan = s.makespan() as u64;
    let total_cycles = (n_iters.max(1) as u64 - 1) * ii + makespan;

    // Static register-pressure checks.
    let (lrf_peak, grf_peak) = register_pressure(mapping, cgra)?;

    // Nodes per modulo slot, topologically ordered within the cycle so a
    // same-cycle producer (a read) runs before its consumers.
    let topo_pos: HashMap<NodeId, usize> =
        g.topo_order().into_iter().enumerate().map(|(i, v)| (v, i)).collect();
    let mut slot_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); s.ii];
    for v in g.nodes() {
        slot_nodes[s.m(v)].push(v);
    }
    for nodes in slot_nodes.iter_mut() {
        nodes.sort_by_key(|&v| topo_pos[&v]);
    }

    // GRF writers per modulo slot (write fires at t(src)+1).
    let mut grf_writer_slots: Vec<Vec<(NodeId, u64)>> = vec![Vec::new(); s.ii];
    for (idx, e) in g.edges().iter().enumerate() {
        if mapping.route_of_edge(idx) == Some(Route::Grf) {
            let t_write = s.t[e.src] as u64 + 1;
            grf_writer_slots[(t_write % ii) as usize].push((e.src, t_write));
        }
    }

    // value_of[v][iter] — produced values (functional state; hardware
    // residency is validated by the pressure stats and hazard checks).
    let mut value_of: Vec<Vec<Option<f32>>> = vec![vec![None; n_iters]; g.len()];
    // Per-member, per-segment output planes, member-kernel-indexed.
    let mut outputs: Vec<Vec<Vec<Vec<f32>>>> = blocks
        .iter()
        .zip(batches)
        .map(|(b, segs)| {
            segs.iter().map(|seg| vec![vec![0.0; b.k]; seg.xs.len()]).collect()
        })
        .collect();
    let mut pe_busy = vec![0u64; cgra.num_pes()];

    for cycle in 0..total_cycles {
        let slot = (cycle % ii) as usize;
        // Per-cycle exclusiveness trackers.
        let mut pe_used: HashMap<crate::arch::PeId, NodeId> = HashMap::new();
        let mut bus_used: HashMap<BusAt, NodeId> = HashMap::new();

        for &v in &slot_nodes[slot] {
            let tv = s.t[v] as u64;
            if cycle < tv {
                continue;
            }
            debug_assert_eq!((cycle - tv) % ii, 0);
            let iter = ((cycle - tv) / ii) as usize;
            if iter >= n_iters {
                continue;
            }

            // PE exclusiveness.
            if let Placement::Pe(pe) = mapping.placements[v] {
                if let Some(prev) = pe_used.insert(pe, v) {
                    return Err(Error::SimFault {
                        cycle,
                        reason: format!("PE {pe} double-booked by {prev} and {v}"),
                    });
                }
                pe_busy[cgra.pe_index(pe)] += 1;
            }

            // Fetch one operand, enforcing bus exclusiveness and hazards.
            let fetch = |edge_idx: usize,
                         bus_used: &mut HashMap<BusAt, NodeId>,
                         value_of: &Vec<Vec<Option<f32>>>|
             -> Result<f32> {
                let e = g.edge(edge_idx);
                debug_assert_eq!(e.dst, v);
                let val = value_of[e.src][iter].ok_or_else(|| Error::SimFault {
                    cycle,
                    reason: format!(
                        "operand {}→{} not produced for iteration {iter}",
                        e.src, e.dst
                    ),
                })?;
                for (bus, value_node) in mapping.bus_claims_of_edge(edge_idx) {
                    if let Some(prev) = bus_used.insert(bus, value_node) {
                        if prev != value_node {
                            return Err(Error::SimFault {
                                cycle,
                                reason: format!("bus {bus:?} carries {prev} and {value_node}"),
                            });
                        }
                    }
                }
                Ok(val)
            };

            match g.kind(v) {
                NodeKind::Read { ch, .. } => {
                    value_of[v][iter] = Some(streams[tags.block_of(v)].input(iter, ch));
                    // The reading itself occupies its column bus this cycle.
                    if let Placement::InputBus(ib) = mapping.placements[v] {
                        if let Some(prev) = bus_used.insert(BusAt::Col { slot, col: ib }, v) {
                            if prev != v {
                                return Err(Error::SimFault {
                                    cycle,
                                    reason: format!("ibus {ib} carries {prev} and {v}"),
                                });
                            }
                        }
                    }
                }
                NodeKind::Mul { ch, kr } => {
                    let (edge_idx, _) = g.in_edges(v).next().expect("mul in-edge");
                    let x = fetch(edge_idx, &mut bus_used, &value_of)?;
                    value_of[v][iter] =
                        Some(x * streams[tags.block_of(v)].weight(iter, ch, kr));
                }
                NodeKind::Add { .. } => {
                    let idxs: Vec<usize> = g.in_edges(v).map(|(i, _)| i).collect();
                    let mut acc = 0.0f32;
                    for edge_idx in idxs {
                        acc += fetch(edge_idx, &mut bus_used, &value_of)?;
                    }
                    value_of[v][iter] = Some(acc);
                }
                NodeKind::Cop { .. } => {
                    let (edge_idx, _) = g.in_edges(v).next().expect("cop in-edge");
                    let x = fetch(edge_idx, &mut bus_used, &value_of)?;
                    value_of[v][iter] = Some(x);
                }
                NodeKind::Write { kr } => {
                    let (edge_idx, _) = g.in_edges(v).next().expect("write in-edge");
                    let y = fetch(edge_idx, &mut bus_used, &value_of)?;
                    let bi = tags.block_of(v);
                    if let Some((seg, local)) = streams[bi].locate(iter) {
                        outputs[bi][seg][local][kr] = y;
                    }
                    value_of[v][iter] = Some(y);
                }
            }
        }

        // GRF write-port accounting for this cycle.
        let mut writers: Vec<NodeId> = Vec::new();
        for &(src, t_write) in &grf_writer_slots[slot] {
            if cycle >= t_write && ((cycle - t_write) / ii) < n_iters as u64 {
                if !writers.contains(&src) {
                    writers.push(src);
                }
            }
        }
        if writers.len() > cgra.grf_write_ports {
            return Err(Error::SimFault {
                cycle,
                reason: format!(
                    "{} GRF writes in one cycle (ports {})",
                    writers.len(),
                    cgra.grf_write_ports
                ),
            });
        }
    }

    // Per-member schedule statistics plus per-segment cycle attribution
    // (shared with the compiled backend — see `attribute_segments`).
    let stats = per_block_stats(s, tags);
    let total_req_iters: u64 = streams.iter().map(|st| st.total() as u64).sum();
    let per_member = attribute_segments(total_cycles, outputs, stats, total_req_iters);
    Ok(BatchSimResult {
        per_member,
        cycles: total_cycles,
        iterations: n_iters,
        pe_busy,
        lrf_peak,
        grf_peak,
    })
}

/// Static register-pressure analysis: per-PE LRF liveness and GRF
/// liveness, both in the modulo-pipelined steady state.
///
/// An op's result lives in its producer PE's LRF from `t(v)` until its
/// last LRF/bus-forwarded consumer fires at `t(v) + max_dist`; with
/// iterations overlapping every `II` cycles, modulo slot `m` holds one
/// copy per offset `j ∈ [0, max_dist)` with `(t(v) + j) ≡ m (mod II)`.
/// The per-PE peak is the maximum over slots of the summed live copies —
/// slot-accurate, unlike a per-op register sum, which would misreport
/// many short-lived values in *different* slots of one PE (the normal
/// shape of wide and fused mappings, where a PE hosts an op in most
/// slots) as simultaneous pressure.
fn register_pressure(mapping: &Mapping, cgra: &StreamingCgra) -> Result<(usize, usize)> {
    let s = &mapping.s;
    let g = &s.g;
    let ii = s.ii;
    // lrf[pe][slot] — live LRF values on `pe` during modulo slot `slot`.
    let mut lrf: Vec<Vec<usize>> = vec![vec![0; ii]; cgra.num_pes()];
    let mut grf = 0usize;
    for v in g.nodes() {
        let Placement::Pe(pe) = mapping.placements[v] else { continue };
        let max_dist = g
            .out_edges(v)
            .filter(|(idx, e)| {
                e.kind == EdgeKind::Internal
                    && mapping.route_of_edge(*idx) != Some(Route::Grf)
            })
            .map(|(_, e)| s.t[e.dst] - s.t[v])
            .max()
            .unwrap_or(1);
        let row = &mut lrf[cgra.pe_index(pe)];
        for j in 0..max_dist {
            row[(s.t[v] + j) % ii] += 1;
        }
    }
    for (idx, e) in g.edges().iter().enumerate() {
        if mapping.route_of_edge(idx) == Some(Route::Grf) {
            grf += (s.t[e.dst] - s.t[e.src]).saturating_sub(1).div_ceil(ii).max(1);
        }
    }
    let lrf_peak = lrf.iter().flatten().copied().max().unwrap_or(0);
    if lrf_peak > cgra.lrf_capacity {
        return Err(Error::SimFault {
            cycle: 0,
            reason: format!("LRF pressure {lrf_peak} exceeds capacity {}", cgra.lrf_capacity),
        });
    }
    if grf > cgra.grf_capacity {
        return Err(Error::SimFault {
            cycle: 0,
            reason: format!("GRF pressure {grf} exceeds capacity {}", cgra.grf_capacity),
        });
    }
    Ok((lrf_peak, grf))
}

/// Convenience: simulate with a deterministic synthetic input stream and
/// verify the outputs against [`SparseBlock::forward`].
pub fn simulate_and_check(
    mapping: &Mapping,
    block: &SparseBlock,
    cgra: &StreamingCgra,
    n_iters: usize,
    seed: u64,
) -> Result<SimResult> {
    let mut rng = crate::util::rng::Pcg64::seeded(seed);
    let xs: Vec<Vec<f32>> = (0..n_iters)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect();
    let res = simulate(mapping, block, cgra, &xs)?;
    for (i, x) in xs.iter().enumerate() {
        let want = block.forward(x);
        for (kr, (&got, &w)) in res.outputs[i].iter().zip(&want).enumerate() {
            if (got - w).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(Error::SimFault {
                    cycle: 0,
                    reason: format!("output mismatch iter {i} kernel {kr}: {got} vs {w}"),
                });
            }
        }
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::{map_block, MapperOptions};
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn simulates_paper_blocks_correctly() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks().iter().take(4) {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())
                .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
            let res = simulate_and_check(&out.mapping, &nb.block, &cgra, 24, 7)
                .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
            assert_eq!(res.iterations, 24);
            // Steady-state throughput approaches 1/II.
            let want = 1.0 / out.mapping.ii as f64;
            assert!(
                (res.throughput() - want).abs() / want < 0.35,
                "{}: throughput {} vs 1/II {}",
                nb.label,
                res.throughput(),
                want
            );
        }
    }

    #[test]
    fn detects_corrupted_placement() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[1];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let mut bad = out.mapping.clone();
        // Collapse two same-slot ops onto one PE: simulator must fault.
        let ops: Vec<usize> =
            bad.s.g.nodes().filter(|&v| bad.s.g.kind(v).is_pe_op()).collect();
        'outer: for (i, &a) in ops.iter().enumerate() {
            for &b in ops.iter().skip(i + 1) {
                if bad.s.m(a) == bad.s.m(b) {
                    bad.placements[b] = bad.placements[a];
                    break 'outer;
                }
            }
        }
        let err = simulate_and_check(&bad, &nb.block, &cgra, 8, 3);
        assert!(err.is_err(), "simulator must catch PE double-booking");
    }

    #[test]
    fn zero_cycle_results_report_zero_not_nan() {
        // A zero-iteration run can produce cycles == 0 (empty schedule):
        // the utilization/throughput accessors must degrade to 0.0, not
        // NaN — serving metrics aggregate these values.
        let empty = SimResult {
            outputs: Vec::new(),
            cycles: 0,
            iterations: 0,
            pe_busy: Vec::new(),
            lrf_peak: 0,
            grf_peak: 0,
        };
        assert_eq!(empty.pe_utilization(), 0.0);
        assert_eq!(empty.throughput(), 0.0);
        let fused = FusedSimResult {
            per_block: Vec::new(),
            cycles: 0,
            iterations: 0,
            pe_busy: vec![0; 16],
            lrf_peak: 0,
            grf_peak: 0,
        };
        assert_eq!(fused.pe_utilization(), 0.0);
        assert_eq!(fused.throughput(), 0.0);
    }

    #[test]
    fn empty_stream_is_finite_in_both_backends() {
        // An empty input stream (zero iterations) still pays the mapping's
        // makespan once; the derived rates stay finite on the interpreter
        // path and the compiled plan agrees on the cycle count.
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let res = simulate(&out.mapping, &nb.block, &cgra, &[]).unwrap();
        assert_eq!(res.iterations, 0);
        assert!(res.pe_utilization().is_finite());
        assert!(res.throughput().is_finite());
        assert_eq!(res.throughput(), 0.0, "no iterations → zero throughput");
        let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
        let batches: Vec<Vec<MemberSegment<'_>>> = vec![Vec::new()];
        let planned = execute_plan_batch(&plan, &[&nb.block], &batches).unwrap();
        assert_eq!(planned.cycles, res.cycles);
        assert_eq!(planned.iterations, 0);
    }

    #[test]
    fn utilization_is_sane() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[2];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let res = simulate_and_check(&out.mapping, &nb.block, &cgra, 32, 5).unwrap();
        let u = res.pe_utilization();
        assert!(u > 0.2 && u <= 1.0, "utilization {u}");
        assert!(res.lrf_peak <= cgra.lrf_capacity);
        assert!(res.grf_peak <= cgra.grf_capacity);
    }

    #[test]
    fn fused_simulation_reports_per_member_outputs() {
        use crate::mapper::map_bundle;
        use crate::sparse::fuse::FusedBundle;
        use std::sync::Arc;
        let cgra = StreamingCgra::paper_default();
        let members: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(2)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let bundle = FusedBundle::new(members.clone()).unwrap();
        let out = map_bundle(&bundle, &cgra, &MapperOptions::fused())
            .unwrap_or_else(|e| panic!("two-block bundle must map: {e}"));
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        let streams: Vec<Vec<Vec<f32>>> = members
            .iter()
            .map(|b| {
                (0..6)
                    .map(|_| (0..b.c).map(|_| rng.next_normal() as f32).collect())
                    .collect()
            })
            .collect();
        let blocks: Vec<&SparseBlock> = members.iter().map(|b| b.as_ref()).collect();
        let xs: Vec<&[Vec<f32>]> = streams.iter().map(|s| s.as_slice()).collect();
        let res = simulate_fused(&out.mapping, &out.tags, &blocks, &cgra, &xs).unwrap();
        assert_eq!(res.per_block.len(), 2);
        assert_eq!(res.iterations, 6);
        for (bi, (b, stream)) in blocks.iter().zip(&streams).enumerate() {
            let got = &res.per_block[bi].outputs;
            assert_eq!(got.len(), 6);
            for (x, y) in stream.iter().zip(got) {
                let want = b.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "member {bi}: {a} vs {w}");
                }
            }
        }
        // Per-member statistics partition the mapping's global counts.
        let cops: usize = res.per_block.iter().map(|b| b.cops).sum();
        let mcids: usize = res.per_block.iter().map(|b| b.mcids).sum();
        assert_eq!(cops, out.mapping.cops());
        assert_eq!(mcids, out.mapping.mcids());
        // Mismatched member/stream counts are rejected.
        assert!(simulate_fused(&out.mapping, &out.tags, &blocks[..1], &cgra, &xs).is_err());
        assert!(simulate_fused(&out.mapping, &out.tags, &blocks, &cgra, &xs[..1]).is_err());
    }

    #[test]
    fn batched_fused_pass_matches_per_request_passes_bitwise() {
        use crate::mapper::map_bundle;
        use crate::sparse::fuse::FusedBundle;
        use std::sync::Arc;
        let cgra = StreamingCgra::paper_default();
        let members: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(2)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let bundle = FusedBundle::new(members.clone()).unwrap();
        let out = map_bundle(&bundle, &cgra, &MapperOptions::fused()).unwrap();
        let blocks: Vec<&SparseBlock> = members.iter().map(|b| b.as_ref()).collect();

        let mut rng = crate::util::rng::Pcg64::seeded(23);
        let mut stream = |b: &SparseBlock, n: usize| -> Vec<Vec<f32>> {
            (0..n)
                .map(|_| (0..b.c).map(|_| rng.next_normal() as f32).collect())
                .collect()
        };
        // Member 0 carries two requests (3 + 2 iters), member 1 one (4):
        // lockstep length 5, member 1 padded with one zero iteration.
        let a1 = stream(&members[0], 3);
        let a2 = stream(&members[0], 2);
        let b1 = stream(&members[1], 4);
        let batches = vec![
            vec![
                MemberSegment { block: &members[0], xs: &a1 },
                MemberSegment { block: &members[0], xs: &a2 },
            ],
            vec![MemberSegment { block: &members[1], xs: &b1 }],
        ];
        let res = simulate_fused_batch(&out.mapping, &out.tags, &blocks, &cgra, &batches)
            .unwrap();
        assert_eq!(res.iterations, 5);
        assert_eq!(res.per_member[0].segments.len(), 2);
        assert_eq!(res.per_member[1].segments.len(), 1);

        // Every segment bit-matches a dedicated whole-bundle pass carrying
        // just that request (zero inputs on the co-resident member) — the
        // passes per-request fused serving used to run one at a time.
        let mut serial_cycles = 0u64;
        for (bi, segs) in [(0usize, vec![&a1, &a2]), (1usize, vec![&b1])] {
            for (si, seg) in segs.iter().enumerate() {
                let zero_streams: Vec<Vec<Vec<f32>>> = members
                    .iter()
                    .enumerate()
                    .map(|(mi, m)| {
                        if mi == bi {
                            (*seg).clone()
                        } else {
                            vec![vec![0.0; m.c]; seg.len()]
                        }
                    })
                    .collect();
                let xs: Vec<&[Vec<f32>]> =
                    zero_streams.iter().map(|s| s.as_slice()).collect();
                let solo =
                    simulate_fused(&out.mapping, &out.tags, &blocks, &cgra, &xs).unwrap();
                serial_cycles += solo.cycles;
                let got = &res.per_member[bi].segments[si].outputs;
                let want = &solo.per_block[bi].outputs;
                assert_eq!(got.len(), want.len(), "member {bi} segment {si}");
                for (it, (gv, wv)) in got.iter().zip(want).enumerate() {
                    for (kr, (a, w)) in gv.iter().zip(wv).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            w.to_bits(),
                            "member {bi} segment {si} iter {it} kernel {kr}"
                        );
                    }
                }
            }
        }
        // Cycle attribution sums exactly to the single pass's total, and
        // the batched pass beats the serial per-request passes.
        let attributed: u64 = res
            .per_member
            .iter()
            .flat_map(|m| m.segments.iter().map(|s| s.cycles))
            .sum();
        assert_eq!(attributed, res.cycles);
        assert!(
            res.cycles < serial_cycles,
            "one batched pass ({}) must undercut {} serial cycles",
            res.cycles,
            serial_cycles
        );
        // Per-member stats still echo the schedule's.
        let cops: usize = res.per_member.iter().map(|m| m.cops).sum();
        assert_eq!(cops, out.mapping.cops());
        // A segment with a foreign mask structure is rejected.
        let alien = paper_blocks()[3].block.clone();
        let alien_xs = stream(&alien, 2);
        let bad = vec![
            vec![MemberSegment { block: &alien, xs: &alien_xs }],
            vec![MemberSegment { block: &members[1], xs: &b1 }],
        ];
        assert!(
            simulate_fused_batch(&out.mapping, &out.tags, &blocks, &cgra, &bad).is_err()
        );
    }
}
