//! Compiled execution plans: the serving tier's fast simulation backend.
//!
//! [`ExecPlan::compile`] lowers a verified `(Mapping, BlockTags,
//! StreamingCgra)` triple ONCE into a flattened op array with every
//! per-cycle decision of the scalar interpreter resolved ahead of time:
//! operand sources (producer register plus its physical transport — LRF
//! distance, GRF index, or claimed bus hops), weight indices
//! `(member, channel, kernel)`, and output routing.
//! [`execute_plan_batch`] then runs a whole request window as tight
//! per-iteration inner loops over the op array — no HashMaps, no
//! `BlockTags` provenance lookups, no per-cycle dispatch — and
//! [`super::lanes`] lifts the same sweep lane-major so one pass over the
//! ops evaluates a whole chunk of lockstep iterations (the serving
//! default).
//!
//! ## Why execution cannot fault
//!
//! The scalar interpreter ([`super::simulate_fused_batch`]) doubles as a
//! bug detector: it re-checks PE exclusiveness, bus exclusiveness and GRF
//! write ports every cycle. Those hazards are *static* properties of a
//! modulo-scheduled mapping — node `v` occupies the same resources in
//! every iteration — so the plan compiler runs the full battery once
//! ([`Mapping::verify`], the register-pressure analysis, a per-slot GRF
//! write-port check) and **compilation fails** wherever the interpreter
//! would fault. What remains at execution time is pure arithmetic,
//! evaluated in the interpreter's exact operand order (f32 addition is
//! order-sensitive), so results stay bit-identical —
//! `tests/sim_equivalence.rs` holds the two backends together on every
//! field of [`BatchSimResult`].
//!
//! Plans are compiled at coordinator registration time under the mapping
//! cache's single-flight guard, cached alongside the mapping in its LRU
//! entry, and evicted with it. The interpreter is NOT retired: it is the
//! differential oracle, per the crate's hot-path-rewrite discipline, and
//! the `[coordinator] sim_backend` knob (`SPARSEMAP_SIM_BACKEND` env
//! override) swaps it back onto the serving path end to end.

use crate::arch::StreamingCgra;
use crate::bind::{Mapping, Placement, Route};
use crate::dfg::fuse::BlockTags;
use crate::dfg::{EdgeKind, NodeId, NodeKind};
use crate::error::{Error, Result};
use crate::mapper::{per_block_stats, BlockStats, MapOutcome};
use crate::sparse::SparseBlock;

use super::lanes::ExecScratch;
use super::{
    attribute_segments, build_member_streams, register_pressure, BatchSimResult, MemberSegment,
    MemberStream,
};

/// Pre-resolved physical transport of one operand, fixed at compile time.
/// Execution reads only the producer register; the hop records what the
/// compiler validated (and what introspection reads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hop {
    /// Broadcast on input (column) bus `col` — the producer is a read.
    InputBus { col: u32 },
    /// Held in the producer PE's LRF for `dist` cycles.
    Lrf { dist: u32 },
    /// Parked in the global register file (dense plan-local index, one
    /// per GRF-routed edge in edge order).
    Grf { index: u32 },
    /// Bus-routed PE→PE transfer claiming `hops` row/column buses (0 for
    /// a same-PE or mesh-neighbour transfer).
    Bus { hops: u32 },
    /// Write-back on output (row) bus `row`.
    OutputBus { row: u32 },
}

/// One pre-resolved operand: producer register plus physical transport.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Operand {
    /// Producer's register in the per-iteration value array (node ids
    /// double as register indices).
    pub src: u32,
    /// The transport the compiler resolved for this fetch.
    pub hop: Hop,
}

/// One entry of the flattened op array, every index resolved ahead of
/// time. `dst` is the node's own register. Shared with [`super::lanes`],
/// which replays the same ops lane-major.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(in crate::sim) enum PlanOp {
    /// Stream channel `ch` of member `member`'s input into `dst`.
    Read { dst: u32, member: u32, ch: u32 },
    /// `dst = a · weight(member, ch, kr)` — weights resolve per segment.
    Mul { dst: u32, a: Operand, member: u32, ch: u32, kr: u32 },
    /// Sum `len` operands starting at `first` in the operand pool, in the
    /// graph's predecessor order (f32 addition order is semantics).
    Add { dst: u32, first: u32, len: u32 },
    /// Caching operation: pass the operand through.
    Cop { dst: u32, a: Operand },
    /// Write kernel `kr` of member `member`'s output for the owning
    /// segment (padded iterations discard the value).
    Write { dst: u32, a: Operand, member: u32, kr: u32 },
}

/// A mapping compiled into a flat execution program.
///
/// Compilation is deterministic — compiling the same
/// `(Mapping, BlockTags, StreamingCgra)` twice yields structurally
/// identical plans (`PartialEq` holds; `tests/sim_equivalence.rs` locks
/// the property) — so a cached plan is a pure function of its cache key.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPlan {
    pub(in crate::sim) ii: usize,
    pub(in crate::sim) makespan: u64,
    pub(in crate::sim) members: usize,
    pub(in crate::sim) n_nodes: usize,
    /// Ops in schedule-time order `(t(v), topo position)`: a valid
    /// topological order for every lockstep iteration and exactly the
    /// order the interpreter visits one iteration's nodes.
    pub(in crate::sim) ops: Vec<PlanOp>,
    /// Flattened Add-operand pool (predecessor order per Add).
    pub(in crate::sim) operands: Vec<Operand>,
    /// Scheduled node count per PE (row-major). Every placed node fires
    /// exactly once per lockstep iteration, so `pe_busy` is this times
    /// the iteration count — the closed form of the interpreter's
    /// per-cycle busy accounting.
    pub(in crate::sim) pe_nodes: Vec<u64>,
    /// Per-member schedule statistics (COPs / MCIDs).
    pub(in crate::sim) stats: Vec<BlockStats>,
    pub(in crate::sim) lrf_peak: usize,
    pub(in crate::sim) grf_peak: usize,
}

fn missing_operand(v: NodeId, what: &str) -> Error {
    Error::Workload(format!("{what} node {v} has no operand edge"))
}

impl ExecPlan {
    /// Compile a mapping into an execution plan, running the full static
    /// battery the interpreter otherwise re-checks per cycle: compilation
    /// fails — instead of producing a plan that could fault mid-window —
    /// on any mapping the interpreter would reject.
    pub fn compile(
        mapping: &Mapping,
        tags: &BlockTags,
        cgra: &StreamingCgra,
    ) -> Result<ExecPlan> {
        let s = &mapping.s;
        let g = &s.g;
        if tags.len() != g.len() {
            return Err(Error::Workload(format!(
                "block tags cover {} nodes but the mapping has {}",
                tags.len(),
                g.len()
            )));
        }
        // PE/bus exclusiveness and routing invariants, once instead of
        // per cycle (hazards are static under modulo scheduling).
        mapping.verify(cgra)?;
        let (lrf_peak, grf_peak) = register_pressure(mapping, cgra)?;
        let ii = s.ii;

        // GRF write ports, statically: a slot's writers recur every II
        // cycles, so the steady-state count per slot must fit the ports
        // (the interpreter checks the same set cycle by cycle). Dense
        // GRF indices are handed out in edge order along the way.
        let mut writers_per_slot: Vec<Vec<NodeId>> = vec![Vec::new(); ii];
        let mut grf_index: Vec<Option<u32>> = vec![None; g.edges().len()];
        let mut next_grf = 0u32;
        for (idx, e) in g.edges().iter().enumerate() {
            if mapping.route_of_edge(idx) == Some(Route::Grf) {
                grf_index[idx] = Some(next_grf);
                next_grf += 1;
                let slot = (s.t[e.src] + 1) % ii;
                if !writers_per_slot[slot].contains(&e.src) {
                    writers_per_slot[slot].push(e.src);
                }
            }
        }
        for (slot, writers) in writers_per_slot.iter().enumerate() {
            if writers.len() > cgra.grf_write_ports {
                return Err(Error::SimFault {
                    cycle: slot as u64,
                    reason: format!(
                        "{} GRF writes in one cycle (ports {})",
                        writers.len(),
                        cgra.grf_write_ports
                    ),
                });
            }
        }

        // Resolve one operand edge into (producer register, transport).
        let operand_of = |idx: usize| -> Result<Operand> {
            let e = g.edge(idx);
            let hop = match e.kind {
                EdgeKind::Input => match mapping.placements[e.src] {
                    Placement::InputBus(col) => Hop::InputBus { col: col as u32 },
                    _ => {
                        return Err(Error::Workload(format!(
                            "read {} not on an input bus",
                            e.src
                        )))
                    }
                },
                EdgeKind::Output => match mapping.placements[e.dst] {
                    Placement::OutputBus(row) => Hop::OutputBus { row: row as u32 },
                    _ => {
                        return Err(Error::Workload(format!(
                            "write {} not on an output bus",
                            e.dst
                        )))
                    }
                },
                EdgeKind::Internal => match mapping.route_of_edge(idx) {
                    Some(Route::Grf) => Hop::Grf {
                        index: grf_index[idx].expect("grf-routed edge was indexed above"),
                    },
                    Some(Route::Lrf) => {
                        Hop::Lrf { dist: (s.t[e.dst] - s.t[e.src]) as u32 }
                    }
                    Some(Route::Bus) => {
                        Hop::Bus { hops: mapping.bus_claims_of_edge(idx).len() as u32 }
                    }
                    None => {
                        return Err(Error::RouteFailed {
                            ii: mapping.ii,
                            reason: format!("internal dep {}→{} unrouted", e.src, e.dst),
                        })
                    }
                },
            };
            Ok(Operand { src: e.src as u32, hop })
        };

        // Flatten in schedule-time order `(t(v), topo position)`: deps
        // satisfy t(src) ≤ t(dst), and the topo tiebreak puts same-cycle
        // producers (reads) before their consumers — the interpreter's
        // in-slot dispatch order, replayed iteration by iteration.
        let topo = g.topo_order();
        let mut topo_pos = vec![0usize; g.len()];
        for (i, &v) in topo.iter().enumerate() {
            topo_pos[v] = i;
        }
        let mut order: Vec<NodeId> = g.nodes().collect();
        order.sort_by_key(|&v| (s.t[v], topo_pos[v]));

        let mut ops = Vec::with_capacity(g.len());
        let mut operands = Vec::new();
        let mut pe_nodes = vec![0u64; cgra.num_pes()];
        for v in order {
            if let Placement::Pe(pe) = mapping.placements[v] {
                pe_nodes[cgra.pe_index(pe)] += 1;
            }
            let member = tags.block_of(v) as u32;
            let dst = v as u32;
            let op = match g.kind(v) {
                NodeKind::Read { ch, .. } => PlanOp::Read { dst, member, ch: ch as u32 },
                NodeKind::Mul { ch, kr } => {
                    let (idx, _) =
                        g.in_edges(v).next().ok_or_else(|| missing_operand(v, "mul"))?;
                    PlanOp::Mul {
                        dst,
                        a: operand_of(idx)?,
                        member,
                        ch: ch as u32,
                        kr: kr as u32,
                    }
                }
                NodeKind::Add { .. } => {
                    let first = operands.len() as u32;
                    for (idx, _) in g.in_edges(v) {
                        operands.push(operand_of(idx)?);
                    }
                    let len = operands.len() as u32 - first;
                    PlanOp::Add { dst, first, len }
                }
                NodeKind::Cop { .. } => {
                    let (idx, _) =
                        g.in_edges(v).next().ok_or_else(|| missing_operand(v, "cop"))?;
                    PlanOp::Cop { dst, a: operand_of(idx)? }
                }
                NodeKind::Write { kr } => {
                    let (idx, _) =
                        g.in_edges(v).next().ok_or_else(|| missing_operand(v, "write"))?;
                    PlanOp::Write { dst, a: operand_of(idx)?, member, kr: kr as u32 }
                }
            };
            ops.push(op);
        }

        Ok(ExecPlan {
            ii,
            makespan: s.makespan() as u64,
            members: tags.members(),
            n_nodes: g.len(),
            ops,
            operands,
            pe_nodes,
            stats: per_block_stats(s, tags),
            lrf_peak,
            grf_peak,
        })
    }

    /// Compile the plan for a mapper outcome — the coordinator's entry
    /// point (see [`MapOutcome::plan_inputs`]).
    pub fn for_outcome(outcome: &MapOutcome, cgra: &StreamingCgra) -> Result<ExecPlan> {
        let (mapping, tags) = outcome.plan_inputs();
        ExecPlan::compile(mapping, tags, cgra)
    }

    /// Initiation interval of the compiled mapping.
    pub fn ii(&self) -> usize {
        self.ii
    }

    /// Member count the plan serves (1 for an unfused block).
    pub fn members(&self) -> usize {
        self.members
    }

    /// Flattened op count (== node count of the source graph).
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }
}

/// Run a batched request window off a compiled plan: the plan-backed twin
/// of [`super::simulate_fused_batch`], bit-identical on every field of
/// [`BatchSimResult`] (`tests/sim_equivalence.rs` enforces this).
/// `blocks`/`batches` follow the same member-roster contract and
/// malformed windows are rejected with the same errors; mapping-level
/// hazards cannot occur here — they failed compilation instead.
pub fn execute_plan_batch(
    plan: &ExecPlan,
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
) -> Result<BatchSimResult> {
    execute_plan_batch_with(plan, blocks, batches, &mut ExecScratch::new())
}

/// [`execute_plan_batch`] with a caller-owned [`ExecScratch`]: the
/// serving tier keeps one scratch per worker thread, so steady-state
/// windows allocate nothing beyond their output planes. This is the
/// scalar (one-iteration-at-a-time) sweep; the serving default is its
/// lane-vectorized twin, [`super::lanes::execute_plan_lanes_with`].
pub fn execute_plan_batch_with(
    plan: &ExecPlan,
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
    scratch: &mut ExecScratch,
) -> Result<BatchSimResult> {
    let streams = build_member_streams(plan.members, blocks, batches)?;
    let n_iters = streams.iter().map(MemberStream::total).max().unwrap_or(0);
    let mut outputs = alloc_outputs(blocks, batches);
    scalar_sweep(plan, &streams, &mut outputs, n_iters, scratch);
    Ok(package_result(plan, &streams, outputs, n_iters))
}

/// Per-member, per-segment output planes, member-kernel-indexed and
/// zero-filled — padded iterations never write, so untouched slots stay
/// zero. Shared by the scalar and lane sweeps.
pub(in crate::sim) fn alloc_outputs(
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
) -> Vec<Vec<Vec<Vec<f32>>>> {
    blocks
        .iter()
        .zip(batches)
        .map(|(b, segs)| {
            segs.iter().map(|seg| vec![vec![0.0; b.k]; seg.xs.len()]).collect()
        })
        .collect()
}

/// The scalar op sweep, one lockstep iteration at a time — the lane
/// backend's width-1 tier and the `[coordinator] sim_lanes = 1` serving
/// path, kept as the mid-tier differential oracle between the
/// interpreter and the vectorized lanes.
pub(in crate::sim) fn scalar_sweep(
    plan: &ExecPlan,
    streams: &[MemberStream<'_>],
    outputs: &mut [Vec<Vec<Vec<f32>>>],
    n_iters: usize,
    scratch: &mut ExecScratch,
) {
    // Structure-of-arrays per-iteration state: one register per node,
    // rewritten every iteration (values are functional per iteration —
    // no cross-iteration state survives, which also makes stale scratch
    // contents harmless), plus each member's segment location resolved
    // once per iteration instead of once per node.
    let (values, locs) = scratch.scalar(plan.n_nodes, plan.members);
    for iter in 0..n_iters {
        for (m, st) in streams.iter().enumerate() {
            locs[m] = st.locate(iter);
        }
        for op in &plan.ops {
            match *op {
                PlanOp::Read { dst, member, ch } => {
                    let m = member as usize;
                    values[dst as usize] = streams[m].input_at(locs[m], ch as usize);
                }
                PlanOp::Mul { dst, a, member, ch, kr } => {
                    let m = member as usize;
                    let w = streams[m].weight_at(locs[m], ch as usize, kr as usize);
                    values[dst as usize] = values[a.src as usize] * w;
                }
                PlanOp::Add { dst, first, len } => {
                    let mut acc = 0.0f32;
                    for o in &plan.operands[first as usize..(first + len) as usize] {
                        acc += values[o.src as usize];
                    }
                    values[dst as usize] = acc;
                }
                PlanOp::Cop { dst, a } => {
                    values[dst as usize] = values[a.src as usize];
                }
                PlanOp::Write { dst, a, member, kr } => {
                    let m = member as usize;
                    let y = values[a.src as usize];
                    if let Some((seg, local)) = locs[m] {
                        outputs[m][seg][local][kr as usize] = y;
                    }
                    values[dst as usize] = y;
                }
            }
        }
    }
}

/// Package a sweep's outputs into a [`BatchSimResult`] via the closed
/// forms both plan sweeps share: total cycles from the modulo schedule,
/// `pe_busy` from per-PE node counts, and segment attribution through
/// [`attribute_segments`] (so rounding can never drift between tiers).
pub(in crate::sim) fn package_result(
    plan: &ExecPlan,
    streams: &[MemberStream<'_>],
    outputs: Vec<Vec<Vec<Vec<f32>>>>,
    n_iters: usize,
) -> BatchSimResult {
    let total_cycles = (n_iters.max(1) as u64 - 1) * plan.ii as u64 + plan.makespan;
    let pe_busy: Vec<u64> = plan.pe_nodes.iter().map(|&c| c * n_iters as u64).collect();
    let total_req_iters: u64 = streams.iter().map(|st| st.total() as u64).sum();
    let per_member =
        attribute_segments(total_cycles, outputs, plan.stats.clone(), total_req_iters);
    BatchSimResult {
        per_member,
        cycles: total_cycles,
        iterations: n_iters,
        pe_busy,
        lrf_peak: plan.lrf_peak,
        grf_peak: plan.grf_peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::{map_block, MapperOptions};
    use crate::sim::simulate_fused_batch;
    use crate::sparse::gen::paper_blocks;

    fn stream(c: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n).map(|_| (0..c).map(|_| rng.next_normal() as f32).collect()).collect()
    }

    #[test]
    fn plan_backed_window_matches_interpreter_bitwise() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
        assert_eq!(plan.members(), 1);
        assert_eq!(plan.ii(), out.mapping.ii);
        assert_eq!(plan.num_ops(), out.mapping.s.g.len());
        let a = stream(nb.block.c, 5, 3);
        let b = stream(nb.block.c, 2, 4);
        let batches = vec![vec![
            MemberSegment { block: &nb.block, xs: &a },
            MemberSegment { block: &nb.block, xs: &b },
        ]];
        let blocks = [&nb.block];
        let want =
            simulate_fused_batch(&out.mapping, &out.tags, &blocks, &cgra, &batches).unwrap();
        let got = execute_plan_batch(&plan, &blocks, &batches).unwrap();
        assert_eq!(got.cycles, want.cycles);
        assert_eq!(got.iterations, want.iterations);
        assert_eq!(got.pe_busy, want.pe_busy);
        assert_eq!(got.lrf_peak, want.lrf_peak);
        assert_eq!(got.grf_peak, want.grf_peak);
        for (gm, wm) in got.per_member.iter().zip(&want.per_member) {
            assert_eq!(gm.cops, wm.cops);
            assert_eq!(gm.mcids, wm.mcids);
            for (gs, ws) in gm.segments.iter().zip(&wm.segments) {
                assert_eq!(gs.cycles, ws.cycles);
                for (gv, wv) in gs.outputs.iter().zip(&ws.outputs) {
                    for (x, y) in gv.iter().zip(wv) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn compile_fails_where_the_interpreter_would_fault() {
        // Collapse two same-slot PE ops onto one PE (the corruption
        // sim::tests::detects_corrupted_placement feeds the interpreter):
        // the static battery must reject it at compile time.
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[1];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let mut bad = out.mapping.clone();
        let ops: Vec<usize> =
            bad.s.g.nodes().filter(|&v| bad.s.g.kind(v).is_pe_op()).collect();
        'outer: for (i, &a) in ops.iter().enumerate() {
            for &b in ops.iter().skip(i + 1) {
                if bad.s.m(a) == bad.s.m(b) {
                    bad.placements[b] = bad.placements[a];
                    break 'outer;
                }
            }
        }
        assert!(
            ExecPlan::compile(&bad, &out.tags, &cgra).is_err(),
            "plan compilation must catch PE double-booking"
        );
    }

    #[test]
    fn compilation_is_deterministic() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[2];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let a = ExecPlan::for_outcome(&out, &cgra).unwrap();
        let b = ExecPlan::for_outcome(&out, &cgra).unwrap();
        assert_eq!(a, b, "compiling the same mapping twice must yield identical plans");
    }
}
