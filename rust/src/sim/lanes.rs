//! Lane-vectorized plan execution: one `PlanOp` sweep evaluates a whole
//! chunk of a window's lockstep iterations at once.
//!
//! A batching window is the ideal SIMD shape — every iteration runs the
//! *same* compiled configuration over *different* inputs — yet the scalar
//! sweep in [`super::plan`] walks the op array once per iteration. This
//! module regroups the plan's structure-of-arrays state lane-major
//! (`values[reg][lane]`: `L` consecutive lockstep iterations per chunk,
//! drawn across all segments of the window) so a single pass over
//! `plan.ops` evaluates `L` iterations: `Read`/`Mul`/`Cop`/`Write` become
//! per-lane loops over contiguous `f32` rows that LLVM auto-vectorizes,
//! and `Add` accumulates the operand pool per lane in predecessor order.
//!
//! ## Bit-identical at any width
//!
//! Lanes are fully independent — no cross-iteration state exists in the
//! plan semantics, and lane `l` performs the interpreter's exact
//! per-iteration arithmetic in the exact operand order (f32 addition
//! order is semantics, so reordering *within* a lane would change bits;
//! widening *across* lanes cannot). Ragged and absent members reuse the
//! existing zero-input padding: a padded lane streams zeros, resolves
//! fallback weights, and its `Write`s are masked off per lane, so
//! [`super::attribute_segments`] and every other closed-form field of
//! [`BatchSimResult`] are untouched. `tests/sim_equivalence.rs` holds the
//! interpreter, the scalar plan sweep, and this backend bit-identical at
//! every supported width.
//!
//! ## Scratch pooling
//!
//! All transient state — the lane-major register file, per-lane segment
//! locations, the lane-major input gather and the per-member uniform
//! weight-source flags — lives in an [`ExecScratch`] that grows
//! monotonically to the largest plan it has served. The serving tier
//! keeps one per worker thread (`coordinator::pool`), so steady-state
//! windows allocate nothing beyond their output planes;
//! [`ExecScratch::grows`] makes the reuse assertable.

use crate::error::{Error, Result};
use crate::sparse::SparseBlock;

use super::plan::{self, ExecPlan, PlanOp};
use super::{build_member_streams, BatchSimResult, MemberSegment, MemberStream};

/// Widest supported lane chunk. Wide enough for one AVX2/NEON register
/// row per op; wider chunks only add padding overhead on the short
/// windows serving actually sees.
pub const MAX_LANES: usize = 8;

/// Pick a lane width for a window of `n_iters` lockstep iterations: the
/// widest supported chunk not exceeding the window. Padding lanes do
/// real (masked-off) arithmetic, so a window smaller than one chunk runs
/// narrow — or scalar — instead of mostly-padding.
pub fn auto_width(n_iters: usize) -> usize {
    match n_iters {
        0..=1 => 1,
        2..=3 => 2,
        4..=7 => 4,
        _ => MAX_LANES,
    }
}

/// Weight resolution mode of one member for one lane chunk.
#[derive(Clone, Copy, Debug, Default)]
enum UniformSrc {
    /// Every lane of the chunk sits in the same segment (`Some`) or is
    /// padding (`None`): one weight lookup broadcasts across the chunk.
    Uniform(Option<usize>),
    /// The chunk straddles a segment boundary: per-lane resolution.
    #[default]
    Mixed,
}

/// Reusable plan-execution scratch: the scalar sweep's SoA state plus
/// the lane backend's gather/scatter staging. Buffers grow monotonically
/// (never shrink their capacity), so a scratch pooled per worker thread
/// reaches a steady state where serving another window of any
/// already-seen size performs no allocation — asserted via
/// [`Self::grows`].
///
/// Stale contents are harmless by construction: ops execute in schedule
/// order, where every register is written before it is read within one
/// sweep, and per-lane segment locations are restaged per chunk.
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// Lane-major register file: `values[reg * L + lane]` (the scalar
    /// sweep uses it at `L = 1`).
    values: Vec<f32>,
    /// Per-member, per-lane segment locations: `locs[member * L + lane]`.
    locs: Vec<Option<(usize, usize)>>,
    /// Lane-major input gather, member-major: channel `ch` of member `m`
    /// occupies `gather[offsets[m] + ch * L ..][..L]`.
    gather: Vec<f32>,
    /// Per-member start of the gather region (in `f32` slots).
    gather_offsets: Vec<usize>,
    /// Per-member weight resolution mode for the current chunk.
    uniform: Vec<UniformSrc>,
    /// Times any buffer outgrew its capacity (see [`Self::grows`]).
    grows: u64,
}

/// Grow `buf` to `len` elements, counting a capacity growth (a `resize`
/// within capacity never allocates — that is the steady state).
fn ensure<T: Clone + Default>(buf: &mut Vec<T>, len: usize, grows: &mut u64) {
    if len > buf.capacity() {
        *grows += 1;
    }
    buf.resize(len, T::default());
}

impl ExecScratch {
    /// A fresh, empty scratch — it sizes itself to each plan it serves.
    pub fn new() -> Self {
        Self::default()
    }

    /// How many times any internal buffer had to allocate. A pooled
    /// scratch in steady state serves window after window without this
    /// moving — the property `coordinator::pool` relies on and the
    /// scratch-reuse tests assert.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// The scalar sweep's view: one register per node, one location per
    /// member.
    pub(in crate::sim) fn scalar(
        &mut self,
        n_nodes: usize,
        members: usize,
    ) -> (&mut [f32], &mut [Option<(usize, usize)>]) {
        ensure(&mut self.values, n_nodes, &mut self.grows);
        ensure(&mut self.locs, members, &mut self.grows);
        (&mut self.values[..n_nodes], &mut self.locs[..members])
    }

    /// Size every lane buffer for `lanes`-wide execution of a plan with
    /// `n_nodes` registers over the given member roster.
    fn ensure_lanes(&mut self, n_nodes: usize, blocks: &[&SparseBlock], lanes: usize) {
        ensure(&mut self.values, n_nodes * lanes, &mut self.grows);
        ensure(&mut self.locs, blocks.len() * lanes, &mut self.grows);
        ensure(&mut self.uniform, blocks.len(), &mut self.grows);
        if blocks.len() > self.gather_offsets.capacity() {
            self.grows += 1;
        }
        self.gather_offsets.clear();
        let mut off = 0usize;
        for b in blocks {
            self.gather_offsets.push(off);
            off += b.c * lanes;
        }
        ensure(&mut self.gather, off, &mut self.grows);
    }
}

/// Run a batched request window on the lane-vectorized backend. `lanes`
/// follows the `[coordinator] sim_lanes` contract: `0` picks a width
/// from the window size ([`auto_width`]), `1` pins the scalar plan
/// sweep, `2`/`4`/`8` force a fixed width (shorter windows pad). Other
/// values are rejected. Allocates its own scratch — the serving tier
/// uses [`execute_plan_lanes_with`] with a pooled one.
pub fn execute_plan_lanes(
    plan: &ExecPlan,
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
    lanes: usize,
) -> Result<BatchSimResult> {
    let mut scratch = ExecScratch::new();
    execute_plan_lanes_with(plan, blocks, batches, lanes, &mut scratch).map(|(res, _)| res)
}

/// [`execute_plan_lanes`] with a caller-owned scratch. Returns the
/// result plus the lane width actually used (`1` = the scalar sweep ran
/// — what the serving tier's `lane_windows` counter distinguishes).
pub fn execute_plan_lanes_with(
    plan: &ExecPlan,
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
    lanes: usize,
    scratch: &mut ExecScratch,
) -> Result<(BatchSimResult, usize)> {
    if !matches!(lanes, 0 | 1 | 2 | 4 | MAX_LANES) {
        return Err(Error::Config(format!(
            "sim lane width must be 0 (auto), 1 (scalar) or one of {{2, 4, {MAX_LANES}}}, \
             got {lanes}"
        )));
    }
    let streams = build_member_streams(plan.members, blocks, batches)?;
    let n_iters = streams.iter().map(MemberStream::total).max().unwrap_or(0);
    let width = if lanes == 0 { auto_width(n_iters) } else { lanes };
    let mut outputs = plan::alloc_outputs(blocks, batches);
    match width {
        1 => plan::scalar_sweep(plan, &streams, &mut outputs, n_iters, scratch),
        2 => sweep::<2>(plan, &streams, blocks, &mut outputs, n_iters, scratch),
        4 => sweep::<4>(plan, &streams, blocks, &mut outputs, n_iters, scratch),
        _ => sweep::<MAX_LANES>(plan, &streams, blocks, &mut outputs, n_iters, scratch),
    }
    Ok((plan::package_result(plan, &streams, outputs, n_iters), width))
}

/// The lane-major op sweep: each pass of the outer loop stages and
/// evaluates `L` consecutive lockstep iterations. Monomorphized per
/// width so every inner loop has a compile-time trip count `L` —
/// contiguous `[f32; L]` rows LLVM turns into vector code.
fn sweep<const L: usize>(
    plan: &ExecPlan,
    streams: &[MemberStream<'_>],
    blocks: &[&SparseBlock],
    outputs: &mut [Vec<Vec<Vec<f32>>>],
    n_iters: usize,
    scratch: &mut ExecScratch,
) {
    scratch.ensure_lanes(plan.n_nodes, blocks, L);
    let ExecScratch { values, locs, gather, gather_offsets, uniform, .. } = scratch;
    let mut base = 0usize;
    while base < n_iters {
        // Stage the chunk: per-lane segment locations, each member's
        // weight resolution mode, and the lane-major input gather. Lanes
        // past the window (`base + l >= n_iters`) are padding — `locate`
        // yields `None`, so they stream zero inputs, resolve fallback
        // weights, and the `Write` mask discards their outputs: exactly
        // the interpreter's treatment of padded iterations.
        for (m, st) in streams.iter().enumerate() {
            let lane_locs = &mut locs[m * L..(m + 1) * L];
            for (l, loc) in lane_locs.iter_mut().enumerate() {
                *loc = st.locate(base + l);
            }
            let seg0 = lane_locs[0].map(|(seg, _)| seg);
            uniform[m] = if lane_locs.iter().all(|loc| loc.map(|(seg, _)| seg) == seg0) {
                UniformSrc::Uniform(seg0)
            } else {
                UniformSrc::Mixed
            };
            let go = gather_offsets[m];
            for ch in 0..blocks[m].c {
                let row = &mut gather[go + ch * L..go + (ch + 1) * L];
                for (slot, loc) in row.iter_mut().zip(lane_locs.iter()) {
                    *slot = st.input_at(*loc, ch);
                }
            }
        }

        for op in &plan.ops {
            match *op {
                PlanOp::Read { dst, member, ch } => {
                    let go = gather_offsets[member as usize] + ch as usize * L;
                    let d = dst as usize * L;
                    values[d..d + L].copy_from_slice(&gather[go..go + L]);
                }
                PlanOp::Mul { dst, a, member, ch, kr } => {
                    let m = member as usize;
                    let (ch, kr) = (ch as usize, kr as usize);
                    // The source row is copied out first: src != dst in
                    // the DAG, but the register file is one slice.
                    let mut x = [0.0f32; L];
                    let s = a.src as usize * L;
                    x.copy_from_slice(&values[s..s + L]);
                    let d = dst as usize * L;
                    let dst_row = &mut values[d..d + L];
                    match uniform[m] {
                        UniformSrc::Uniform(seg) => {
                            let w = streams[m].weight_source(seg).weight(ch, kr);
                            for (slot, &xv) in dst_row.iter_mut().zip(&x) {
                                *slot = xv * w;
                            }
                        }
                        UniformSrc::Mixed => {
                            let lane_locs = &locs[m * L..(m + 1) * L];
                            for ((slot, &xv), loc) in
                                dst_row.iter_mut().zip(&x).zip(lane_locs)
                            {
                                *slot = xv * streams[m].weight_at(*loc, ch, kr);
                            }
                        }
                    }
                }
                PlanOp::Add { dst, first, len } => {
                    // Operands in predecessor order per lane — the
                    // interpreter's exact f32 summation order.
                    let mut acc = [0.0f32; L];
                    for o in &plan.operands[first as usize..(first + len) as usize] {
                        let s = o.src as usize * L;
                        for (a, &v) in acc.iter_mut().zip(&values[s..s + L]) {
                            *a += v;
                        }
                    }
                    let d = dst as usize * L;
                    values[d..d + L].copy_from_slice(&acc);
                }
                PlanOp::Cop { dst, a } => {
                    let s = a.src as usize * L;
                    values.copy_within(s..s + L, dst as usize * L);
                }
                PlanOp::Write { dst, a, member, kr } => {
                    let m = member as usize;
                    let mut y = [0.0f32; L];
                    let s = a.src as usize * L;
                    y.copy_from_slice(&values[s..s + L]);
                    let out = &mut outputs[m];
                    for (loc, &yv) in locs[m * L..(m + 1) * L].iter().zip(&y) {
                        if let Some((seg, local)) = *loc {
                            out[seg][local][kr as usize] = yv;
                        }
                    }
                    let d = dst as usize * L;
                    values[d..d + L].copy_from_slice(&y);
                }
            }
        }
        base += L;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::StreamingCgra;
    use crate::mapper::{map_block, MapperOptions};
    use crate::sim::execute_plan_batch;
    use crate::sparse::gen::paper_blocks;

    fn stream(c: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n).map(|_| (0..c).map(|_| rng.next_normal() as f32).collect()).collect()
    }

    fn assert_bitwise(a: &BatchSimResult, b: &BatchSimResult, ctx: &str) {
        assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
        assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
        assert_eq!(a.pe_busy, b.pe_busy, "{ctx}: pe_busy");
        for (am, bm) in a.per_member.iter().zip(&b.per_member) {
            for (asg, bsg) in am.segments.iter().zip(&bm.segments) {
                assert_eq!(asg.cycles, bsg.cycles, "{ctx}: segment cycles");
                for (av, bv) in asg.outputs.iter().zip(&bsg.outputs) {
                    for (x, y) in av.iter().zip(bv) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: output bits");
                    }
                }
            }
        }
    }

    #[test]
    fn auto_width_picks_the_widest_fitting_chunk() {
        assert_eq!(auto_width(0), 1);
        assert_eq!(auto_width(1), 1);
        assert_eq!(auto_width(2), 2);
        assert_eq!(auto_width(3), 2);
        assert_eq!(auto_width(4), 4);
        assert_eq!(auto_width(7), 4);
        assert_eq!(auto_width(8), 8);
        assert_eq!(auto_width(1000), MAX_LANES);
    }

    #[test]
    fn invalid_lane_widths_are_rejected() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
        let xs = stream(nb.block.c, 3, 1);
        let batches = vec![vec![MemberSegment { block: &nb.block, xs: &xs }]];
        for bad in [3usize, 5, 6, 7, 9, 16] {
            let err = execute_plan_lanes(&plan, &[&nb.block], &batches, bad);
            assert!(err.is_err(), "lane width {bad} must be rejected");
        }
    }

    #[test]
    fn every_width_matches_the_scalar_sweep_bitwise() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
        // Ragged two-segment window: 5 + 2 iterations — smaller than one
        // 8-wide chunk, and the 2/4-wide chunks straddle the boundary.
        let a = stream(nb.block.c, 5, 3);
        let b = stream(nb.block.c, 2, 4);
        let batches = vec![vec![
            MemberSegment { block: &nb.block, xs: &a },
            MemberSegment { block: &nb.block, xs: &b },
        ]];
        let blocks = [&nb.block];
        let want = execute_plan_batch(&plan, &blocks, &batches).unwrap();
        let mut scratch = ExecScratch::new();
        for lanes in [0usize, 1, 2, 4, 8] {
            let (got, width) =
                execute_plan_lanes_with(&plan, &blocks, &batches, lanes, &mut scratch)
                    .unwrap();
            if lanes > 0 {
                assert_eq!(width, lanes, "explicit widths are honoured");
            } else {
                assert_eq!(width, auto_width(7));
            }
            assert_bitwise(&got, &want, &format!("lanes={lanes}"));
        }
    }

    #[test]
    fn pooled_scratch_stops_allocating_in_steady_state() {
        let cgra = StreamingCgra::paper_default();
        let blocks = paper_blocks();
        let nb = &blocks[0];
        let other = &blocks[1];
        let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
        let oout = map_block(&other.block, &cgra, &MapperOptions::sparsemap()).unwrap();
        let oplan = ExecPlan::for_outcome(&oout, &cgra).unwrap();
        let mut scratch = ExecScratch::new();
        let run = |scratch: &mut ExecScratch, plan: &ExecPlan, b: &SparseBlock, n, seed| {
            let xs = stream(b.c, n, seed);
            let batches = vec![vec![MemberSegment { block: b, xs: &xs }]];
            execute_plan_lanes_with(plan, &[b], &batches, 0, scratch).unwrap().0
        };
        // Warm up on the largest shapes this worker will see (both
        // plans, both window sizes) ...
        run(&mut scratch, &plan, &nb.block, 16, 1);
        run(&mut scratch, &oplan, &other.block, 16, 2);
        run(&mut scratch, &plan, &nb.block, 3, 3);
        let grown = scratch.grows();
        assert!(grown > 0, "first windows must size the scratch");
        // ... then steady state: window after window, zero growth, and
        // results still match fresh-scratch runs bitwise (stale lanes
        // from a *different* plan must not leak).
        for seed in 10..30u64 {
            let n = 1 + (seed as usize % 16);
            let pooled = run(&mut scratch, &plan, &nb.block, n, seed);
            let fresh = run(&mut ExecScratch::new(), &plan, &nb.block, n, seed);
            assert_bitwise(&pooled, &fresh, &format!("seed={seed}"));
            let pooled = run(&mut scratch, &oplan, &other.block, n, seed);
            let fresh = run(&mut ExecScratch::new(), &oplan, &other.block, n, seed);
            assert_bitwise(&pooled, &fresh, &format!("other seed={seed}"));
        }
        assert_eq!(
            scratch.grows(),
            grown,
            "steady-state windows must not grow the pooled scratch"
        );
    }
}
