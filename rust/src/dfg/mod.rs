//! The sparse data-flow graph (s-DFG, paper §3.1 def. 1).
//!
//! `V_D = V_M ∪ V_A ∪ V_R ∪ V_W` (multiplications, additions, input
//! readings, output writings) plus the caching operations (COPs) the
//! scheduler may insert. `E_D = E_R ∪ E_W ∪ E_I` (input, output, internal
//! dependencies).
//!
//! Nodes in `V_R`/`V_W` are *operated on buses*; everything else occupies a
//! PE. Edge timing rules (§3.2 constraint (1)):
//! * input dep `(r, op)`:   `t(op) = t(r)`   (no buffer on input buses);
//! * output dep `(op, w)`:  `t(w) = t(op)+1` (no buffer on output buses);
//! * internal dep `(a, b)`: `t(b) ≥ t(a)+1`; distance `> 1` makes it an
//!   **MCID**.

pub mod analysis;
pub mod build;
pub mod fuse;
pub mod oracle;

use crate::error::{Error, Result};

/// Node index inside an [`SDfg`].
pub type NodeId = usize;

/// What a node is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeKind {
    /// Input reading for channel `ch`. `replica > 0` marks a Mul-CI
    /// multicast copy (an extra input-bus allocation of the same data).
    Read { ch: usize, replica: usize },
    /// Multiplication `x[ch] · w[ch, kr]`.
    Mul { ch: usize, kr: usize },
    /// Adder-tree addition inside kernel `kr`.
    Add { kr: usize },
    /// Output writing of kernel `kr`.
    Write { kr: usize },
    /// Caching operation: occupies a PE to hold a value whose producer and
    /// consumers could not be co-scheduled. `for_read == true` for input
    /// caches (paper Fig. 4(b)), false for output-side COPs (§4.1 ③).
    Cop { for_read: bool },
}

impl NodeKind {
    /// Whether this node executes on a PE (counts against `N·M` per slot).
    pub fn is_pe_op(&self) -> bool {
        matches!(self, NodeKind::Mul { .. } | NodeKind::Add { .. } | NodeKind::Cop { .. })
    }

    pub fn is_read(&self) -> bool {
        matches!(self, NodeKind::Read { .. })
    }

    pub fn is_write(&self) -> bool {
        matches!(self, NodeKind::Write { .. })
    }
}

/// Dependency class (§3.1 def. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EdgeKind {
    /// `E_R`: read → PE-op, scheduling distance exactly 0.
    Input,
    /// `E_W`: PE-op → write, scheduling distance exactly 1.
    Output,
    /// `E_I`: PE-op → PE-op, distance ≥ 1 (> 1 ⇒ MCID).
    Internal,
}

/// A directed dependency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub kind: EdgeKind,
}

/// The sparse data-flow graph.
#[derive(Clone, Debug, Default)]
pub struct SDfg {
    pub name: String,
    kinds: Vec<NodeKind>,
    edges: Vec<Edge>,
    /// Outgoing edge indices per node.
    succ: Vec<Vec<usize>>,
    /// Incoming edge indices per node.
    pred: Vec<Vec<usize>>,
}

impl SDfg {
    pub fn new(name: &str) -> Self {
        SDfg { name: name.to_string(), ..Default::default() }
    }

    pub fn add_node(&mut self, kind: NodeKind) -> NodeId {
        self.kinds.push(kind);
        self.succ.push(Vec::new());
        self.pred.push(Vec::new());
        self.kinds.len() - 1
    }

    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, kind: EdgeKind) -> usize {
        debug_assert!(src < self.len() && dst < self.len());
        let idx = self.edges.len();
        self.edges.push(Edge { src, dst, kind });
        self.succ[src].push(idx);
        self.pred[dst].push(idx);
        idx
    }

    /// Re-point an edge's source (used by Mul-CI to move a mul's input
    /// dependency onto a multicast replica, and by COP insertion).
    pub fn retarget_edge_src(&mut self, edge_idx: usize, new_src: NodeId) {
        let old_src = self.edges[edge_idx].src;
        self.succ[old_src].retain(|&e| e != edge_idx);
        self.edges[edge_idx].src = new_src;
        self.succ[new_src].push(edge_idx);
    }

    /// Change an edge's kind (e.g. Input → Internal when a COP interposes).
    pub fn set_edge_kind(&mut self, edge_idx: usize, kind: EdgeKind) {
        self.edges[edge_idx].kind = kind;
    }

    /// Remove all internal edges among the given nodes (RID-AT clears a
    /// kernel's adder-tree wiring before reconstructing it).
    pub fn clear_internal_edges_among(&mut self, nodes: &[NodeId]) {
        let set: std::collections::HashSet<NodeId> = nodes.iter().copied().collect();
        let keep: Vec<Edge> = self
            .edges
            .iter()
            .copied()
            .filter(|e| {
                !(e.kind == EdgeKind::Internal && set.contains(&e.src) && set.contains(&e.dst))
            })
            .collect();
        self.rebuild_from_edges(keep);
    }

    fn rebuild_from_edges(&mut self, edges: Vec<Edge>) {
        self.edges = edges;
        for v in self.succ.iter_mut() {
            v.clear();
        }
        for v in self.pred.iter_mut() {
            v.clear();
        }
        for (idx, e) in self.edges.iter().enumerate() {
            self.succ[e.src].push(idx);
            self.pred[e.dst].push(idx);
        }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn kind(&self, v: NodeId) -> NodeKind {
        self.kinds[v]
    }

    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        0..self.len()
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn edge(&self, idx: usize) -> Edge {
        self.edges[idx]
    }

    /// Outgoing edges of `v`.
    pub fn out_edges(&self, v: NodeId) -> impl Iterator<Item = (usize, Edge)> + '_ {
        self.succ[v].iter().map(move |&i| (i, self.edges[i]))
    }

    /// Incoming edges of `v`.
    pub fn in_edges(&self, v: NodeId) -> impl Iterator<Item = (usize, Edge)> + '_ {
        self.pred[v].iter().map(move |&i| (i, self.edges[i]))
    }

    pub fn successors(&self, v: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.succ[v].iter().map(move |&i| self.edges[i].dst)
    }

    pub fn predecessors(&self, v: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.pred[v].iter().map(move |&i| self.edges[i].src)
    }

    // ---- typed node sets -------------------------------------------------

    pub fn reads(&self) -> Vec<NodeId> {
        self.nodes().filter(|&v| self.kind(v).is_read()).collect()
    }

    pub fn writes(&self) -> Vec<NodeId> {
        self.nodes().filter(|&v| self.kind(v).is_write()).collect()
    }

    /// PE-executed operations (`V_OP ∪ COPs`).
    pub fn pe_ops(&self) -> Vec<NodeId> {
        self.nodes().filter(|&v| self.kind(v).is_pe_op()).collect()
    }

    /// `V_OP` = muls + adds (COPs excluded — the paper counts them
    /// separately as `|M_C|`).
    pub fn v_op(&self) -> Vec<NodeId> {
        self.nodes()
            .filter(|&v| matches!(self.kind(v), NodeKind::Mul { .. } | NodeKind::Add { .. }))
            .collect()
    }

    pub fn cops(&self) -> Vec<NodeId> {
        self.nodes()
            .filter(|&v| matches!(self.kind(v), NodeKind::Cop { .. }))
            .collect()
    }

    /// Multiplications fed by read `r` (its fanout, paper `fanout(r)`).
    pub fn fanout_muls(&self, r: NodeId) -> Vec<NodeId> {
        debug_assert!(self.kind(r).is_read());
        self.successors(r)
            .filter(|&v| matches!(self.kind(v), NodeKind::Mul { .. }))
            .collect()
    }

    /// All nodes of kernel `kr` that sit on a PE (muls + adds), used by
    /// RID-AT.
    pub fn kernel_ops(&self, kr: usize) -> Vec<NodeId> {
        self.nodes()
            .filter(|&v| match self.kind(v) {
                NodeKind::Mul { kr: k2, .. } | NodeKind::Add { kr: k2 } => k2 == kr,
                _ => false,
            })
            .collect()
    }

    /// Structural sanity: degrees per node class, acyclicity, edge-kind
    /// consistency. Called by tests and after every rewrite phase.
    pub fn validate(&self) -> Result<()> {
        for v in self.nodes() {
            let ins: Vec<Edge> = self.in_edges(v).map(|(_, e)| e).collect();
            let outs: Vec<Edge> = self.out_edges(v).map(|(_, e)| e).collect();
            let fail = |msg: String| -> Result<()> {
                Err(Error::Workload(format!("{}: node {} ({:?}): {}", self.name, v, self.kind(v), msg)))
            };
            match self.kind(v) {
                NodeKind::Read { .. } => {
                    if !ins.is_empty() {
                        return fail("read with incoming edges".into());
                    }
                    if outs.iter().any(|e| e.kind != EdgeKind::Input) {
                        return fail("read with non-input out-edge".into());
                    }
                }
                NodeKind::Mul { .. } => {
                    if ins.len() != 1 || ins[0].kind != EdgeKind::Input && ins[0].kind != EdgeKind::Internal {
                        return fail(format!("mul needs exactly 1 in-edge, has {:?}", ins));
                    }
                    if outs.len() != 1 {
                        return fail(format!("mul needs exactly 1 out-edge, has {}", outs.len()));
                    }
                }
                NodeKind::Add { .. } => {
                    let internal_ins =
                        ins.iter().filter(|e| e.kind == EdgeKind::Internal).count();
                    if internal_ins != 2 || ins.len() != 2 {
                        return fail(format!("add needs exactly 2 internal in-edges, has {:?}", ins));
                    }
                    if outs.len() != 1 {
                        return fail(format!("add needs exactly 1 out-edge, has {}", outs.len()));
                    }
                }
                NodeKind::Write { .. } => {
                    if ins.len() != 1 || ins[0].kind != EdgeKind::Output {
                        return fail("write needs exactly 1 output in-edge".into());
                    }
                    if !outs.is_empty() {
                        return fail("write with outgoing edges".into());
                    }
                }
                NodeKind::Cop { for_read } => {
                    if ins.len() != 1 {
                        return fail("cop needs exactly 1 in-edge".into());
                    }
                    let want_in = if for_read { EdgeKind::Input } else { EdgeKind::Internal };
                    if ins[0].kind != want_in {
                        return fail(format!("cop in-edge kind {:?}", ins[0].kind));
                    }
                    if outs.is_empty() {
                        return fail("cop with no consumers".into());
                    }
                }
            }
        }
        // Acyclicity via Kahn's algorithm.
        let mut indeg: Vec<usize> = (0..self.len()).map(|v| self.pred[v].len()).collect();
        let mut queue: Vec<NodeId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(v, _)| v)
            .collect();
        let mut seen = 0;
        while let Some(v) = queue.pop() {
            seen += 1;
            for s in self.successors(v) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if seen != self.len() {
            return Err(Error::Workload(format!("{}: s-DFG has a cycle", self.name)));
        }
        Ok(())
    }

    /// Topological order (panics on cycles — call after `validate`).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<usize> = (0..self.len()).map(|v| self.pred[v].len()).collect();
        let mut queue: std::collections::VecDeque<NodeId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(v, _)| v)
            .collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for s in self.successors(v) {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "cycle in s-DFG");
        order
    }
}
