//! s-DFG analyses shared by the schedulers: association matrix (AIBA's
//! priority signal), fanout statistics, and the MII bound.

use crate::arch::StreamingCgra;
use crate::dfg::{NodeId, NodeKind, SDfg};
use crate::util::KernelMask;

/// Pairwise channel association (paper §2.1: number of kernels requiring
/// both channels), computed once per block and consulted by AIBA on every
/// bus-allocation decision.
///
/// Kernel sets are held as [`KernelMask`]s: the association signal is
/// defined for arbitrary kernel counts, so blocks wider than 64 kernels
/// (ResNet/VGG layers routinely carry 128–512) spill to multi-word masks
/// instead of hitting a width assert. The mask-based build is locked
/// byte-identical to the naive set-based oracle
/// ([`crate::dfg::oracle::build_naive`]) by
/// `tests/association_equivalence.rs`.
#[derive(Clone, Debug)]
pub struct AssociationMatrix {
    /// Read node ids, in the order rows/cols of `assoc` are laid out.
    pub reads: Vec<NodeId>,
    assoc: Vec<u32>,
    n: usize,
    /// Node id → matrix index (usize::MAX for non-reads), so the AIBA
    /// inner loop's lookups are O(1). Sized to the *pristine* graph; nodes
    /// added later by the scheduler (replicas, COPs) resolve to None.
    idx_of: Vec<usize>,
}

impl AssociationMatrix {
    /// Build from the s-DFG structure alone (two reads are associated per
    /// kernel in which both have a multiplication).
    pub fn build(g: &SDfg) -> Self {
        let reads = g.reads();
        let n = reads.len();
        // Kernel set per read: inline u64 for k ≤ 64, multi-word above.
        // One pass over the muls pins the kernel-axis width so every mask
        // is pre-sized (no spill reallocation during the bulk build).
        let nk = g
            .nodes()
            .filter_map(|v| match g.kind(v) {
                NodeKind::Mul { kr, .. } => Some(kr + 1),
                _ => None,
            })
            .max()
            .unwrap_or(0);
        let kernels_of = |r: NodeId| -> KernelMask {
            let mut bits = KernelMask::with_kernels(nk);
            for m in g.fanout_muls(r) {
                if let NodeKind::Mul { kr, .. } = g.kind(m) {
                    bits.insert(kr);
                }
            }
            bits
        };
        let masks: Vec<KernelMask> = reads.iter().map(|&r| kernels_of(r)).collect();
        let mut assoc = vec![0u32; n * n];
        for i in 0..n {
            for j in 0..n {
                assoc[i * n + j] = masks[i].intersection_count(&masks[j]);
            }
        }
        let mut idx_of = vec![usize::MAX; g.len()];
        for (i, &r) in reads.iter().enumerate() {
            idx_of[r] = i;
        }
        AssociationMatrix { reads, assoc, n, idx_of }
    }

    /// Association between the i-th and j-th read (matrix order).
    pub fn by_index(&self, i: usize, j: usize) -> u32 {
        self.assoc[i * self.n + j]
    }

    /// Index of a read node in matrix order (O(1); None for nodes outside
    /// the pristine graph, e.g. Mul-CI replicas).
    pub fn index_of(&self, r: NodeId) -> Option<usize> {
        match self.idx_of.get(r) {
            Some(&i) if i != usize::MAX => Some(i),
            _ => None,
        }
    }

    /// Association of read `r` summed over a set of reads.
    pub fn sum_with(&self, r: NodeId, others: &[NodeId]) -> u32 {
        let Some(i) = self.index_of(r) else { return 0 };
        others
            .iter()
            .filter_map(|&o| self.index_of(o))
            .map(|j| self.by_index(i, j))
            .sum()
    }

    /// Total association of `r` with every other read (AIBA tie-break).
    pub fn total(&self, r: NodeId) -> u32 {
        let Some(i) = self.index_of(r) else { return 0 };
        (0..self.n).filter(|&j| j != i).map(|j| self.by_index(i, j)).sum()
    }
}

/// MII of a graph on a CGRA (§4.1): resource bound over PEs / input buses /
/// output buses. COPs are not included — they are a scheduling artifact.
pub fn mii(g: &SDfg, cgra: &StreamingCgra) -> usize {
    cgra.mii(g.v_op().len(), g.reads().len(), g.writes().len())
}

/// Longest path length (in edges) from any source to any sink — the
/// pipeline depth lower bound, used for simulator sizing and reporting.
pub fn critical_path_len(g: &SDfg) -> usize {
    let order = g.topo_order();
    let mut dist = vec![0usize; g.len()];
    let mut best = 0;
    for &v in &order {
        for s in g.successors(v) {
            dist[s] = dist[s].max(dist[v] + 1);
            best = best.max(dist[s]);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::gen::{paper_blocks, random_block};
    use crate::sparse::SparseBlock;

    #[test]
    fn association_matches_block_definition() {
        let b = random_block("a", 6, 6, 0.4, 3);
        let (g, idx) = build_sdfg(&b);
        let am = AssociationMatrix::build(&g);
        for c1 in 0..6 {
            for c2 in 0..6 {
                let (Some(r1), Some(r2)) = (idx.read(c1), idx.read(c2)) else { continue };
                let (Some(i), Some(j)) = (am.index_of(r1), am.index_of(r2)) else { continue };
                assert_eq!(am.by_index(i, j) as usize, b.association(c1, c2), "({c1},{c2})");
            }
        }
    }

    #[test]
    fn fig3_example_association() {
        // Fig. 3: 4 channels, 4 kernels; c2/c3 have the highest association.
        // Build the paper's example: k0 = c0+c1, k1 = c1+c2+c3, k2 = c2+c3,
        // k3 = c2+c3 (approximation of Fig 3(a)'s adder structure).
        let mask = vec![
            // k0    k1     k2     k3
            true, false, false, false, // c0
            true, true, false, false, // c1
            false, true, true, true, // c2
            false, true, true, true, // c3
        ];
        let b = SparseBlock::from_mask("fig3", 4, 4, mask).unwrap();
        assert_eq!(b.association(2, 3), 3);
        assert!(b.association(2, 3) > b.association(0, 1));
        let (g, idx) = build_sdfg(&b);
        let am = AssociationMatrix::build(&g);
        let r2 = idx.read(2).unwrap();
        let r3 = idx.read(3).unwrap();
        let (i, j) = (am.index_of(r2).unwrap(), am.index_of(r3).unwrap());
        assert_eq!(am.by_index(i, j), 3);
    }

    #[test]
    fn mii_of_paper_blocks() {
        let cgra = StreamingCgra::paper_default();
        let want = [2, 2, 3, 2, 4, 3, 4];
        for (nb, &w) in paper_blocks().iter().zip(&want) {
            let (g, _) = build_sdfg(&nb.block);
            assert_eq!(mii(&g, &cgra), w, "{}", nb.label);
        }
    }

    #[test]
    fn critical_path_reasonable() {
        let b = random_block("c", 8, 8, 0.4, 5);
        let (g, _) = build_sdfg(&b);
        let cp = critical_path_len(&g);
        // read -> mul -> log2(tree) adds -> write.
        assert!(cp >= 3, "cp={cp}");
        assert!(cp <= 2 + 8, "cp={cp}");
    }
}
