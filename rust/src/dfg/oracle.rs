//! Reference ("oracle") implementations retired from the s-DFG analysis hot
//! path, kept alive so the optimized rewrites stay provably equivalent —
//! the same workflow [`crate::bind::oracle`] established for the binder.
//!
//! * [`build_naive`] — set-based association: the kernel set of every read
//!   as a plain sorted `Vec<usize>`, pairwise association by two-pointer
//!   intersection counting. Oracle for the [`crate::util::KernelMask`]-based
//!   [`crate::dfg::analysis::AssociationMatrix::build`], locked
//!   byte-identical by `tests/association_equivalence.rs` over the paper
//!   blocks plus randomized wide blocks (k up to 256, c > 64).
//!
//! Nothing here is on the mapper's search path; allocation costs are
//! irrelevant.

use crate::dfg::{NodeId, NodeKind, SDfg};

/// The association matrix as the naive definition computes it: reads in
/// `SDfg::reads()` order, `assoc[i · n + j]` = number of kernels in which
/// both read `i` and read `j` have a multiplication.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NaiveAssociation {
    /// Read node ids, in the order rows/cols of `assoc` are laid out.
    pub reads: Vec<NodeId>,
    assoc: Vec<u32>,
    n: usize,
}

impl NaiveAssociation {
    /// Association between the i-th and j-th read (matrix order).
    pub fn by_index(&self, i: usize, j: usize) -> u32 {
        self.assoc[i * self.n + j]
    }

    /// Matrix dimension (number of reads).
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// Build the association matrix from plain sorted kernel-index sets — no
/// bitmask, no width limit, no cleverness. This is the paper's §2.1
/// definition transcribed directly.
pub fn build_naive(g: &SDfg) -> NaiveAssociation {
    let reads = g.reads();
    let n = reads.len();
    let kernel_set = |r: NodeId| -> Vec<usize> {
        let mut ks: Vec<usize> = g
            .fanout_muls(r)
            .into_iter()
            .filter_map(|m| match g.kind(m) {
                NodeKind::Mul { kr, .. } => Some(kr),
                _ => None,
            })
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    };
    let sets: Vec<Vec<usize>> = reads.iter().map(|&r| kernel_set(r)).collect();
    let mut assoc = vec![0u32; n * n];
    for i in 0..n {
        for j in 0..n {
            assoc[i * n + j] = sorted_intersection_count(&sets[i], &sets[j]);
        }
    }
    NaiveAssociation { reads, assoc, n }
}

fn sorted_intersection_count(a: &[usize], b: &[usize]) -> u32 {
    let (mut ia, mut ib, mut count) = (0usize, 0usize, 0u32);
    while ia < a.len() && ib < b.len() {
        match a[ia].cmp(&b[ib]) {
            std::cmp::Ordering::Less => ia += 1,
            std::cmp::Ordering::Greater => ib += 1,
            std::cmp::Ordering::Equal => {
                count += 1;
                ia += 1;
                ib += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::gen::random_block;

    #[test]
    fn naive_association_matches_block_definition() {
        let b = random_block("n", 7, 9, 0.4, 11);
        let (g, idx) = build_sdfg(&b);
        let na = build_naive(&g);
        for c1 in 0..b.c {
            for c2 in 0..b.c {
                let (Some(r1), Some(r2)) = (idx.read(c1), idx.read(c2)) else { continue };
                let i = na.reads.iter().position(|&r| r == r1).unwrap();
                let j = na.reads.iter().position(|&r| r == r2).unwrap();
                assert_eq!(na.by_index(i, j) as usize, b.association(c1, c2), "({c1},{c2})");
            }
        }
    }

    #[test]
    fn intersection_count_two_pointer() {
        assert_eq!(sorted_intersection_count(&[1, 3, 5], &[2, 3, 5, 9]), 2);
        assert_eq!(sorted_intersection_count(&[], &[1]), 0);
        assert_eq!(sorted_intersection_count(&[64, 128, 200], &[64, 200]), 2);
    }
}
