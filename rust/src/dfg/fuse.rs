//! Composition of per-block s-DFGs into one block-tagged graph — the IR
//! side of multi-block fusion.
//!
//! A fused bundle's members are independent computations: the composed
//! graph is the disjoint union of the member graphs with **no cross-block
//! dependencies**, plus a [`BlockTags`] provenance table (node → member
//! index). Member node ids are offset contiguously (member `i` occupies
//! `offsets[i]..offsets[i+1]`, in the member's own node order), so a
//! member's subgraph inside the composition is byte-identical to the
//! standalone graph up to a constant id shift — the property the
//! fused-vs-solo differential suite (`tests/fusion_equivalence.rs`) leans
//! on.
//!
//! Downstream stages need no fusion awareness: the conflict-graph build,
//! the SBTS solve and the simulator all operate on the composed graph
//! as-is; only per-block *reporting* (COPs/MCIDs, per-member outputs)
//! consults the tags.

use crate::dfg::{NodeId, SDfg};

/// Node → member-block provenance of a composed graph. For an unfused
/// block the tags are trivial ([`BlockTags::single`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockTags {
    /// Member index per node.
    of_node: Vec<usize>,
    /// Node-id offset per member plus a total-length sentinel:
    /// member `i` owns `offsets[i]..offsets[i+1]`.
    offsets: Vec<usize>,
}

impl BlockTags {
    /// Trivial tags for a single (unfused) graph of `n_nodes` nodes.
    pub fn single(n_nodes: usize) -> Self {
        BlockTags { of_node: vec![0; n_nodes], offsets: vec![0, n_nodes] }
    }

    /// Number of member blocks.
    pub fn members(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Member index of node `v`.
    #[inline]
    pub fn block_of(&self, v: NodeId) -> usize {
        self.of_node[v]
    }

    /// Total node count tagged.
    pub fn len(&self) -> usize {
        self.of_node.len()
    }

    pub fn is_empty(&self) -> bool {
        self.of_node.is_empty()
    }

    /// Node-id range of member `i` inside the composed graph.
    pub fn range_of(&self, i: usize) -> std::ops::Range<NodeId> {
        self.offsets[i]..self.offsets[i + 1]
    }
}

/// Compose disjoint member graphs into one block-tagged graph: nodes of
/// member `i` keep their relative order at offset `offsets[i]`; edges are
/// re-based per member (grouped by member, in member edge order). Node
/// kinds carry *member-local* channel/kernel indices — the tags
/// disambiguate which block they refer to.
pub fn compose(name: &str, parts: &[&SDfg]) -> (SDfg, BlockTags) {
    let mut g = SDfg::new(name);
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut of_node = Vec::with_capacity(total);
    let mut offsets = Vec::with_capacity(parts.len() + 1);
    for (bi, p) in parts.iter().enumerate() {
        offsets.push(g.len());
        for v in p.nodes() {
            let nv = g.add_node(p.kind(v));
            debug_assert_eq!(nv, offsets[bi] + v);
            of_node.push(bi);
        }
    }
    offsets.push(g.len());
    for (bi, p) in parts.iter().enumerate() {
        let off = offsets[bi];
        for e in p.edges() {
            g.add_edge(e.src + off, e.dst + off, e.kind);
        }
    }
    (g, BlockTags { of_node, offsets })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::gen::random_block;

    #[test]
    fn single_tags_are_trivial() {
        let t = BlockTags::single(5);
        assert_eq!(t.members(), 1);
        assert_eq!(t.len(), 5);
        assert!(!t.is_empty());
        assert_eq!(t.range_of(0), 0..5);
        assert!((0..5).all(|v| t.block_of(v) == 0));
    }

    #[test]
    fn compose_is_disjoint_union_with_provenance() {
        let a = build_sdfg(&random_block("a", 3, 3, 0.4, 1)).0;
        let b = build_sdfg(&random_block("b", 4, 2, 0.5, 2)).0;
        let c = build_sdfg(&random_block("c", 2, 4, 0.3, 3)).0;
        let parts = [&a, &b, &c];
        let (g, tags) = compose("fused(a+b+c)", &parts);

        assert_eq!(g.len(), a.len() + b.len() + c.len());
        assert_eq!(tags.len(), g.len());
        assert_eq!(tags.members(), 3);
        assert_eq!(
            g.edges().len(),
            a.edges().len() + b.edges().len() + c.edges().len()
        );
        // Per-member subgraph is the member graph shifted by a constant.
        for (bi, p) in parts.iter().enumerate() {
            let range = tags.range_of(bi);
            assert_eq!(range.len(), p.len());
            let off = range.start;
            for v in p.nodes() {
                assert_eq!(g.kind(off + v), p.kind(v), "member {bi} node {v}");
                assert_eq!(tags.block_of(off + v), bi);
            }
        }
        // No cross-block edges, and every edge maps back to a member edge.
        for e in g.edges() {
            let bs = tags.block_of(e.src);
            assert_eq!(bs, tags.block_of(e.dst), "cross-block edge {e:?}");
            let off = tags.range_of(bs).start;
            let member = parts[bs];
            assert!(
                member
                    .edges()
                    .iter()
                    .any(|me| me.src == e.src - off && me.dst == e.dst - off && me.kind == e.kind),
                "edge {e:?} missing from member {bs}"
            );
        }
        // The union of valid members is valid.
        g.validate().unwrap();
    }

    #[test]
    fn compose_single_part_matches_original() {
        let a = build_sdfg(&random_block("solo", 4, 4, 0.4, 7)).0;
        let (g, tags) = compose("solo", &[&a]);
        assert_eq!(g.len(), a.len());
        assert_eq!(tags.range_of(0), 0..a.len());
        for v in a.nodes() {
            assert_eq!(g.kind(v), a.kind(v));
        }
        assert_eq!(g.edges(), a.edges());
    }
}
