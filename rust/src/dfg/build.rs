//! s-DFG construction from a [`SparseBlock`].
//!
//! The baseline compilers ([6][12]) map a *fixed* adder tree (balanced
//! binary reduction in channel order); SparseMap treats the tree wiring as
//! reconstructable (RID-AT) but the node multiset is identical — a kernel
//! with `n` multiplications always carries `n − 1` additions.

use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::sparse::SparseBlock;

/// Handles into the built graph, used by schedulers.
#[derive(Clone, Debug, Default)]
pub struct SDfgIndex {
    /// Read node per channel (dense over channels with fanout ≥ 1).
    pub read_of_channel: Vec<(usize, NodeId)>,
    /// Mul node per (channel, kernel) nonzero.
    pub mul_of: Vec<((usize, usize), NodeId)>,
    /// Adds per kernel (in construction order).
    pub adds_of_kernel: Vec<(usize, Vec<NodeId>)>,
    /// Write node per non-empty kernel.
    pub write_of_kernel: Vec<(usize, NodeId)>,
}

impl SDfgIndex {
    pub fn read(&self, ch: usize) -> Option<NodeId> {
        self.read_of_channel.iter().find(|(c, _)| *c == ch).map(|&(_, v)| v)
    }

    pub fn mul(&self, ch: usize, kr: usize) -> Option<NodeId> {
        self.mul_of.iter().find(|((c, k), _)| *c == ch && *k == kr).map(|&(_, v)| v)
    }

    pub fn write(&self, kr: usize) -> Option<NodeId> {
        self.write_of_kernel.iter().find(|(k, _)| *k == kr).map(|&(_, v)| v)
    }
}

/// Build the s-DFG of a block with fixed balanced adder trees.
pub fn build_sdfg(block: &SparseBlock) -> (SDfg, SDfgIndex) {
    let mut g = SDfg::new(&block.name);
    let mut index = SDfgIndex::default();

    // Input readings, channel order.
    for ch in 0..block.c {
        if block.channel_fanout(ch) > 0 {
            let r = g.add_node(NodeKind::Read { ch, replica: 0 });
            index.read_of_channel.push((ch, r));
        }
    }

    // Multiplications with their input dependencies.
    for ch in 0..block.c {
        let Some(r) = index.read(ch) else { continue };
        for kr in block.kernels_of_channel(ch) {
            let m = g.add_node(NodeKind::Mul { ch, kr });
            g.add_edge(r, m, EdgeKind::Input);
            index.mul_of.push(((ch, kr), m));
        }
    }

    // Adder trees (balanced binary reduction in channel order) + writes.
    for kr in 0..block.k {
        let muls: Vec<NodeId> = block
            .channels_of_kernel(kr)
            .into_iter()
            .map(|ch| index.mul(ch, kr).expect("mul exists"))
            .collect();
        if muls.is_empty() {
            continue;
        }
        let mut adds = Vec::new();
        let mut frontier = muls;
        while frontier.len() > 1 {
            let mut next = Vec::with_capacity(frontier.len().div_ceil(2));
            let mut it = frontier.chunks_exact(2);
            for pair in &mut it {
                let a = g.add_node(NodeKind::Add { kr });
                g.add_edge(pair[0], a, EdgeKind::Internal);
                g.add_edge(pair[1], a, EdgeKind::Internal);
                adds.push(a);
                next.push(a);
            }
            if let [odd] = it.remainder() {
                next.push(*odd);
            }
            frontier = next;
        }
        let root = frontier[0];
        let w = g.add_node(NodeKind::Write { kr });
        g.add_edge(root, w, EdgeKind::Output);
        index.adds_of_kernel.push((kr, adds));
        index.write_of_kernel.push((kr, w));
    }

    debug_assert!(g.validate().is_ok(), "freshly built s-DFG must validate");
    (g, index)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{paper_blocks, random_block};

    #[test]
    fn node_counts_match_table2_identities() {
        for nb in paper_blocks() {
            let f = nb.block.features();
            let (g, _) = build_sdfg(&nb.block);
            assert_eq!(g.reads().len(), f.v_r, "{}", nb.label);
            assert_eq!(g.writes().len(), f.v_w, "{}", nb.label);
            assert_eq!(g.v_op().len(), f.v_op, "{}", nb.label);
            assert!(g.cops().is_empty());
            g.validate().unwrap();
        }
    }

    #[test]
    fn adder_tree_shape() {
        // Kernel with n muls gets n-1 adds and a single root feeding the
        // write.
        let b = random_block("t", 8, 8, 0.4, 42);
        let (g, idx) = build_sdfg(&b);
        for (kr, adds) in &idx.adds_of_kernel {
            let n = b.kernel_size(*kr);
            assert_eq!(adds.len(), n.saturating_sub(1), "kernel {kr}");
        }
        g.validate().unwrap();
    }

    #[test]
    fn fanout_muls_match_block() {
        let b = random_block("t", 6, 6, 0.4, 1);
        let (g, idx) = build_sdfg(&b);
        for ch in 0..6 {
            if let Some(r) = idx.read(ch) {
                assert_eq!(g.fanout_muls(r).len(), b.channel_fanout(ch));
            }
        }
    }

    #[test]
    fn single_mul_kernel_feeds_write_directly() {
        // mask: 2 channels, 2 kernels; kernel 1 has exactly one mul.
        let b = crate::sparse::SparseBlock::from_mask(
            "s",
            2,
            2,
            vec![true, false, true, true],
        )
        .unwrap();
        let (g, idx) = build_sdfg(&b);
        let w1 = idx.write(1).unwrap();
        let prod: Vec<_> = g.predecessors(w1).collect();
        assert_eq!(prod.len(), 1);
        assert!(matches!(g.kind(prod[0]), NodeKind::Mul { kr: 1, .. }));
    }

    #[test]
    fn topo_order_respects_edges() {
        let b = random_block("t", 8, 8, 0.3, 7);
        let (g, _) = build_sdfg(&b);
        let order = g.topo_order();
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, &v)| (v, i)).collect();
        for e in g.edges() {
            assert!(pos[&e.src] < pos[&e.dst]);
        }
    }
}
