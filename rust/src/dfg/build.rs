//! s-DFG construction from a [`SparseBlock`].
//!
//! The baseline compilers ([6][12]) map a *fixed* adder tree (balanced
//! binary reduction in channel order); SparseMap treats the tree wiring as
//! reconstructable (RID-AT) but the node multiset is identical — a kernel
//! with `n` multiplications always carries `n − 1` additions.

use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::sparse::SparseBlock;

/// Handles into the built graph, used by schedulers.
///
/// Lookups (`read`/`mul`/`write`) go through dense tables indexed by
/// channel, `(channel, kernel)` and kernel — the per-entry linear scans
/// they replace were O(nnz) per query, which made the adder-tree
/// construction loop quadratic in nnz on wide (k ≥ 96) blocks.
#[derive(Clone, Debug, Default)]
pub struct SDfgIndex {
    /// Adds per kernel (in construction order).
    pub adds_of_kernel: Vec<(usize, Vec<NodeId>)>,
    /// O(1) lookup tables (`ABSENT` = no node): per channel, `(c, k)`
    /// row-major, per kernel.
    read_lut: Vec<NodeId>,
    mul_lut: Vec<NodeId>,
    write_lut: Vec<NodeId>,
    /// Kernel-axis stride of `mul_lut`.
    k: usize,
}

/// Sentinel for "no node" in the dense lookup tables.
const ABSENT: NodeId = usize::MAX;

impl SDfgIndex {
    /// Empty index with lookup tables sized for a `c × k` block.
    fn sized(c: usize, k: usize) -> Self {
        SDfgIndex {
            adds_of_kernel: Vec::new(),
            read_lut: vec![ABSENT; c],
            mul_lut: vec![ABSENT; c * k],
            write_lut: vec![ABSENT; k],
            k,
        }
    }

    fn note_read(&mut self, ch: usize, v: NodeId) {
        self.read_lut[ch] = v;
    }

    fn note_mul(&mut self, ch: usize, kr: usize, v: NodeId) {
        self.mul_lut[ch * self.k + kr] = v;
    }

    fn note_write(&mut self, kr: usize, v: NodeId) {
        self.write_lut[kr] = v;
    }

    pub fn read(&self, ch: usize) -> Option<NodeId> {
        self.read_lut.get(ch).copied().filter(|&v| v != ABSENT)
    }

    pub fn mul(&self, ch: usize, kr: usize) -> Option<NodeId> {
        if self.k == 0 || kr >= self.k {
            return None;
        }
        self.mul_lut.get(ch * self.k + kr).copied().filter(|&v| v != ABSENT)
    }

    pub fn write(&self, kr: usize) -> Option<NodeId> {
        self.write_lut.get(kr).copied().filter(|&v| v != ABSENT)
    }
}

/// Build the s-DFG of a block with fixed balanced adder trees.
pub fn build_sdfg(block: &SparseBlock) -> (SDfg, SDfgIndex) {
    let mut g = SDfg::new(&block.name);
    let mut index = SDfgIndex::sized(block.c, block.k);

    // Input readings, channel order.
    for ch in 0..block.c {
        if block.channel_fanout(ch) > 0 {
            let r = g.add_node(NodeKind::Read { ch, replica: 0 });
            index.note_read(ch, r);
        }
    }

    // Multiplications with their input dependencies.
    for ch in 0..block.c {
        let Some(r) = index.read(ch) else { continue };
        for kr in block.kernels_of_channel(ch) {
            let m = g.add_node(NodeKind::Mul { ch, kr });
            g.add_edge(r, m, EdgeKind::Input);
            index.note_mul(ch, kr, m);
        }
    }

    // Adder trees (balanced binary reduction in channel order) + writes.
    for kr in 0..block.k {
        let muls: Vec<NodeId> = block
            .channels_of_kernel(kr)
            .into_iter()
            .map(|ch| index.mul(ch, kr).expect("mul exists"))
            .collect();
        if muls.is_empty() {
            continue;
        }
        let mut adds = Vec::new();
        let mut frontier = muls;
        while frontier.len() > 1 {
            let mut next = Vec::with_capacity(frontier.len().div_ceil(2));
            let mut it = frontier.chunks_exact(2);
            for pair in &mut it {
                let a = g.add_node(NodeKind::Add { kr });
                g.add_edge(pair[0], a, EdgeKind::Internal);
                g.add_edge(pair[1], a, EdgeKind::Internal);
                adds.push(a);
                next.push(a);
            }
            if let [odd] = it.remainder() {
                next.push(*odd);
            }
            frontier = next;
        }
        let root = frontier[0];
        let w = g.add_node(NodeKind::Write { kr });
        g.add_edge(root, w, EdgeKind::Output);
        index.adds_of_kernel.push((kr, adds));
        index.note_write(kr, w);
    }

    debug_assert!(g.validate().is_ok(), "freshly built s-DFG must validate");
    (g, index)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{paper_blocks, random_block};

    #[test]
    fn node_counts_match_table2_identities() {
        for nb in paper_blocks() {
            let f = nb.block.features();
            let (g, _) = build_sdfg(&nb.block);
            assert_eq!(g.reads().len(), f.v_r, "{}", nb.label);
            assert_eq!(g.writes().len(), f.v_w, "{}", nb.label);
            assert_eq!(g.v_op().len(), f.v_op, "{}", nb.label);
            assert!(g.cops().is_empty());
            g.validate().unwrap();
        }
    }

    #[test]
    fn adder_tree_shape() {
        // Kernel with n muls gets n-1 adds and a single root feeding the
        // write.
        let b = random_block("t", 8, 8, 0.4, 42);
        let (g, idx) = build_sdfg(&b);
        for (kr, adds) in &idx.adds_of_kernel {
            let n = b.kernel_size(*kr);
            assert_eq!(adds.len(), n.saturating_sub(1), "kernel {kr}");
        }
        g.validate().unwrap();
    }

    #[test]
    fn fanout_muls_match_block() {
        let b = random_block("t", 6, 6, 0.4, 1);
        let (g, idx) = build_sdfg(&b);
        for ch in 0..6 {
            if let Some(r) = idx.read(ch) {
                assert_eq!(g.fanout_muls(r).len(), b.channel_fanout(ch));
            }
        }
    }

    #[test]
    fn single_mul_kernel_feeds_write_directly() {
        // mask: 2 channels, 2 kernels; kernel 1 has exactly one mul.
        let b = crate::sparse::SparseBlock::from_mask(
            "s",
            2,
            2,
            vec![true, false, true, true],
        )
        .unwrap();
        let (g, idx) = build_sdfg(&b);
        let w1 = idx.write(1).unwrap();
        let prod: Vec<_> = g.predecessors(w1).collect();
        assert_eq!(prod.len(), 1);
        assert!(matches!(g.kind(prod[0]), NodeKind::Mul { kr: 1, .. }));
    }

    #[test]
    fn index_lookup_tables_match_graph() {
        // Every dense-LUT answer must agree with the graph and the mask:
        // present exactly where the block has structure, with the right
        // node kind; None on absent slots and out-of-range queries.
        let b = random_block("lut", 9, 130, 0.8, 3);
        let (g, idx) = build_sdfg(&b);
        for ch in 0..b.c {
            match idx.read(ch) {
                Some(r) => {
                    assert!(b.channel_fanout(ch) > 0);
                    assert!(matches!(g.kind(r), NodeKind::Read { ch: c2, replica: 0 } if c2 == ch));
                }
                None => assert_eq!(b.channel_fanout(ch), 0, "read({ch})"),
            }
            for kr in 0..b.k {
                match idx.mul(ch, kr) {
                    Some(m) => {
                        assert!(b.has_weight(ch, kr));
                        assert!(matches!(
                            g.kind(m),
                            NodeKind::Mul { ch: c2, kr: k2 } if c2 == ch && k2 == kr
                        ));
                    }
                    None => assert!(!b.has_weight(ch, kr), "mul({ch},{kr})"),
                }
            }
        }
        for kr in 0..b.k {
            match idx.write(kr) {
                Some(w) => {
                    assert!(b.kernel_size(kr) > 0);
                    assert!(matches!(g.kind(w), NodeKind::Write { kr: k2 } if k2 == kr));
                }
                None => assert_eq!(b.kernel_size(kr), 0, "write({kr})"),
            }
        }
        assert_eq!(idx.read(b.c + 5), None);
        assert_eq!(idx.mul(b.c + 5, 0), None);
        assert_eq!(idx.mul(0, b.k + 5), None);
        assert_eq!(idx.write(b.k + 5), None);
        let empty = SDfgIndex::default();
        assert_eq!(empty.read(0), None);
        assert_eq!(empty.mul(0, 0), None);
        assert_eq!(empty.write(0), None);
    }

    #[test]
    fn topo_order_respects_edges() {
        let b = random_block("t", 8, 8, 0.3, 7);
        let (g, _) = build_sdfg(&b);
        let order = g.topo_order();
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, &v)| (v, i)).collect();
        for e in g.edges() {
            assert!(pos[&e.src] < pos[&e.dst]);
        }
    }
}
