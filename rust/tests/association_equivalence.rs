//! Differential suite for the kernel-axis width lift: the
//! `KernelMask`-based `AssociationMatrix::build` must be byte-identical to
//! the naive set-based association oracle (`dfg::oracle::build_naive`) on
//! the paper blocks, the wide-block generators, and ≥100 randomized wide
//! blocks straddling the 64-kernel inline/spill boundary — the cases the
//! retired `assert!(kr < 64)` used to crash on.

use sparsemap::dfg::analysis::AssociationMatrix;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::dfg::oracle::build_naive;
use sparsemap::dfg::SDfg;
use sparsemap::sparse::gen::{paper_blocks, random_block, wide_blocks};
use sparsemap::util::rng::Pcg64;

/// Full matrix comparison: read order, every pairwise entry, and the
/// derived totals the AIBA scheduler consumes.
fn assert_association_identical(g: &SDfg, label: &str) {
    let am = AssociationMatrix::build(g);
    let na = build_naive(g);
    assert_eq!(am.reads, na.reads, "{label}: read order diverged");
    let n = na.len();
    for i in 0..n {
        for j in 0..n {
            assert_eq!(
                am.by_index(i, j),
                na.by_index(i, j),
                "{label}: assoc[{i},{j}] diverged"
            );
        }
    }
    for (i, &r) in na.reads.iter().enumerate() {
        assert_eq!(am.index_of(r), Some(i), "{label}: index_of({r})");
        let want_total: u32 = (0..n).filter(|&j| j != i).map(|j| na.by_index(i, j)).sum();
        assert_eq!(am.total(r), want_total, "{label}: total({r})");
    }
}

#[test]
fn association_matches_oracle_on_paper_blocks() {
    for nb in paper_blocks() {
        let (g, _) = build_sdfg(&nb.block);
        assert_association_identical(&g, nb.label);
    }
}

#[test]
fn association_matches_oracle_on_wide_blocks() {
    for b in wide_blocks() {
        let (g, _) = build_sdfg(&b);
        assert_association_identical(&g, &b.name);
    }
}

#[test]
fn association_matches_oracle_on_randomized_wide_blocks() {
    // ≥100 randomized blocks at the k widths the old u64 assert hid:
    // 63 (last inline index), 64/65 (first spill words), 128, 200.
    let mut rng = Pcg64::seeded(0x51de);
    let mut cases = 0usize;
    for &k in &[63usize, 64, 65, 128, 200] {
        for _ in 0..21 {
            let c = 3 + rng.index(30);
            let p_zero = 0.55 + 0.4 * rng.next_f64();
            let seed = rng.next_u64();
            let b = random_block(&format!("rw_k{k}_s{seed}"), c, k, p_zero, seed);
            let (g, _) = build_sdfg(&b);
            assert_association_identical(&g, &b.name);
            cases += 1;
        }
    }
    assert!(cases >= 100, "suite shrank: {cases} cases");
}

#[test]
fn association_matches_block_definition_across_boundary() {
    // Ground truth straight from the mask, independent of either builder.
    let mut rng = Pcg64::seeded(77);
    for &k in &[63usize, 64, 65, 128] {
        let b = random_block(&format!("def_k{k}"), 10, k, 0.8, rng.next_u64());
        let (g, idx) = build_sdfg(&b);
        let am = AssociationMatrix::build(&g);
        for c1 in 0..b.c {
            for c2 in 0..b.c {
                let (Some(r1), Some(r2)) = (idx.read(c1), idx.read(c2)) else { continue };
                let (i, j) = (am.index_of(r1).unwrap(), am.index_of(r2).unwrap());
                assert_eq!(
                    am.by_index(i, j) as usize,
                    b.association(c1, c2),
                    "k={k} ({c1},{c2})"
                );
            }
        }
    }
}
