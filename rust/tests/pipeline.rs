//! Integration tests: the full mapping pipeline (schedule → route → bind →
//! simulate) across blocks, schedulers and fabric geometries.

use sparsemap::arch::StreamingCgra;
use sparsemap::config::{SchedulerKind, SparsemapConfig, Techniques};
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sim::simulate_and_check;
use sparsemap::sparse::gen::{paper_blocks, random_block};

#[test]
fn every_paper_block_maps_simulates_and_verifies() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    for nb in paper_blocks() {
        let out = map_block(&nb.block, &cgra, &opts)
            .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
        out.mapping.verify(&cgra).unwrap();
        let res = simulate_and_check(&out.mapping, &nb.block, &cgra, 16, 1)
            .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
        assert_eq!(res.iterations, 16);
        // II within two of the lower bound (blocks 5/7 sit at 91 % PE
        // occupancy at MII and may take MII+2 depending on the SBTS seed).
        assert!(
            out.mapping.ii <= out.mii + 2,
            "{}: II {} vs MII {}",
            nb.label,
            out.mapping.ii,
            out.mii
        );
    }
}

#[test]
fn random_blocks_map_and_verify() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    let mut mapped = 0;
    for seed in 0..10u64 {
        let b = random_block(&format!("r{seed}"), 6, 6, 0.5, seed);
        if let Ok(out) = map_block(&b, &cgra, &opts) {
            simulate_and_check(&out.mapping, &b, &cgra, 8, seed).unwrap();
            mapped += 1;
        }
    }
    assert!(mapped >= 8, "only {mapped}/10 random blocks mapped");
}

#[test]
fn wider_fabric_reduces_ii() {
    // A larger PEA must never need a larger II for the same block.
    let small = StreamingCgra::paper_default();
    let large = StreamingCgra::new(6, 6, 8, 8);
    let opts = MapperOptions::sparsemap();
    for nb in paper_blocks().iter().take(3) {
        let a = map_block(&nb.block, &small, &opts).unwrap();
        let b = map_block(&nb.block, &large, &opts).unwrap();
        // Lower resource bound; binding at the very tight II=1 may fall
        // back one step, so allow equality plus one.
        assert!(b.mii <= a.mii, "{}", nb.label);
        assert!(b.mapping.ii <= a.mapping.ii + 1, "{}", nb.label);
    }
}

#[test]
fn techniques_off_matches_baseline_shape() {
    // With all three techniques disabled, SparseMap's scheduler degrades
    // toward the baseline's COP behaviour on high-fanout blocks.
    let cgra = StreamingCgra::paper_default();
    let none = MapperOptions::sparsemap().with_techniques(Techniques {
        aiba: false,
        mul_ci: false,
        rid_at: false,
    });
    let full = MapperOptions::sparsemap();
    let mut cops_none = 0;
    let mut cops_full = 0;
    for nb in paper_blocks() {
        if let Ok(o) = map_block(&nb.block, &cgra, &none) {
            cops_none += o.mapping.cops();
        }
        if let Ok(o) = map_block(&nb.block, &cgra, &full) {
            cops_full += o.mapping.cops();
        }
    }
    assert!(
        cops_full < cops_none,
        "techniques must reduce COPs: {cops_full} vs {cops_none}"
    );
}

#[test]
fn config_driven_pipeline() {
    let cfg = SparsemapConfig::from_str_cfg(
        "[mapper]\nscheduler = \"sparsemap\"\nii_slack = 3\n[workload]\nseed = 5\n",
    )
    .unwrap();
    assert_eq!(cfg.scheduler, SchedulerKind::SparseMap);
    let opts = MapperOptions::from_config(&cfg);
    let nb = &paper_blocks()[1];
    let out = map_block(&nb.block, &cfg.cgra, &opts).unwrap();
    out.mapping.verify(&cfg.cgra).unwrap();
}

#[test]
fn deterministic_end_to_end() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    let nb = &paper_blocks()[4];
    let a = map_block(&nb.block, &cgra, &opts).unwrap();
    let b = map_block(&nb.block, &cgra, &opts).unwrap();
    assert_eq!(a.mapping.ii, b.mapping.ii);
    assert_eq!(a.mapping.placements, b.mapping.placements);
}
