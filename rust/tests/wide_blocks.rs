//! End-to-end coverage of the wide-kernel-axis workload class (k > 64,
//! c > 64): the blocks the association matrix's retired `u64` kernel mask
//! used to panic on must now schedule, bind, simulate and serve through
//! the coordinator on the paper's 4×4 fabric.
//!
//! Wide shapes sit far from the paper blocks' operating point (II ≈ k/N
//! instead of 2–4), so the mapper gets a wider II slack and a reduced SBTS
//! budget here — the point is that the pipeline is *open* for the class,
//! not that it hits MII.

use std::sync::Arc;

use sparsemap::arch::StreamingCgra;
use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::Coordinator;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sim::simulate;
use sparsemap::sparse::gen::wide_blocks;
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

fn wide_block(name: &str) -> SparseBlock {
    wide_blocks().into_iter().find(|b| b.name == name).unwrap_or_else(|| {
        panic!("wide block {name} missing from generator")
    })
}

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

#[test]
fn k128_block_maps_simulates_end_to_end() {
    let cgra = StreamingCgra::paper_default();
    let b = wide_block("wide_k128");
    let out = map_block(&b, &cgra, &MapperOptions::wide())
        .unwrap_or_else(|e| panic!("wide_k128 must map: {e}"));
    out.mapping.verify(&cgra).unwrap();
    // The output buses bound II from below at ⌈k/N⌉ regardless of sparsity.
    assert!(out.mapping.ii >= b.k.div_ceil(cgra.n), "II {} vs k {}", out.mapping.ii, b.k);

    let xs = stream_for(&b, 3, 41);
    let res = simulate(&out.mapping, &b, &cgra, &xs).unwrap();
    for (x, y) in xs.iter().zip(&res.outputs) {
        let want = b.forward(x);
        assert_eq!(y.len(), want.len());
        for (a, w) in y.iter().zip(&want) {
            assert!((a - w).abs() <= 1e-4 * (1.0 + w.abs()), "{a} vs {w}");
        }
    }
}

#[test]
fn c96_block_maps_and_verifies() {
    // The channel axis past 64: 96 reads through 4 input buses.
    let cgra = StreamingCgra::paper_default();
    let b = wide_block("wide_c96");
    let out = map_block(&b, &cgra, &MapperOptions::wide())
        .unwrap_or_else(|e| panic!("wide_c96 must map: {e}"));
    out.mapping.verify(&cgra).unwrap();
    assert!(out.mapping.ii >= b.c.div_ceil(cgra.m));
}

#[test]
fn coordinator_serves_wide_blocks() {
    // The serving path end-to-end on a mixed narrow/wide request stream:
    // mapping cache, worker pool and simulator all see k = 128.
    let wide_point = MapperOptions::wide();
    let mut cfg = SparsemapConfig::default();
    cfg.workers = 2;
    cfg.queue_depth = 4;
    cfg.mis_iterations = wide_point.mis_iterations;
    cfg.ii_slack = wide_point.ii_slack;
    let coord = Coordinator::new(&cfg);

    let wide = Arc::new(wide_block("wide_k128"));
    let narrow = Arc::new(sparsemap::sparse::gen::paper_blocks()[0].block.clone());
    let wide_xs = stream_for(&wide, 2, 7);
    let mut session = coord.session();
    let mut tickets = Vec::new();
    for _ in 0..2 {
        tickets.push(session.enqueue(Arc::clone(&wide), wide_xs.clone()));
    }
    tickets.push(session.enqueue(Arc::clone(&narrow), stream_for(&narrow, 4, 8)));

    for (i, ticket) in tickets.into_iter().enumerate() {
        let r = ticket.wait().expect("wide serving job ok");
        assert_eq!(r.id, i as u64);
        if i < 2 {
            assert_eq!(r.block_name, "wide_k128");
            assert_eq!(r.outputs.len(), 2);
            for (x, y) in wide_xs.iter().zip(&r.outputs) {
                let want = wide.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() <= 1e-4 * (1.0 + w.abs()), "{a} vs {w}");
                }
            }
        }
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.jobs, 3);
    assert_eq!(m.failures, 0);
    assert_eq!(m.cache_misses, 2, "wide + narrow → exactly two mappings");
    assert_eq!(m.cache_hits, 1);
}
