//! Integration: the PJRT runtime path vs the CGRA simulator path — the two
//! executions of the same sparse block must agree (L1/L2 artifacts ↔ L3
//! fabric). Skipped when `make artifacts` has not run.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::runtime::{default_artifacts_dir, Runtime};
use sparsemap::sim::simulate;
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::rng::Pcg64;

/// The executor needs `make artifacts` *and* the `pjrt` + `pjrt-xla`
/// features (the default offline build — and the CI-checked
/// `--features pjrt` leg — ship a stub runtime; see `sparsemap::runtime`).
fn artifacts_available() -> bool {
    cfg!(feature = "pjrt-xla")
        && std::path::Path::new(&default_artifacts_dir()).join("manifest.tsv").exists()
}

#[test]
fn pjrt_and_simulator_agree_on_sparse_blocks() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cgra = StreamingCgra::paper_default();
    let mut rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let opts = MapperOptions::sparsemap();

    // Pair each artifact variant with a matching paper block.
    for (artifact, label) in [("sb_c4k6", "block1"), ("sb_c6k6", "block3"), ("sb_c8k8", "block6")]
    {
        let nb = paper_blocks().into_iter().find(|n| n.label == label).unwrap();
        let spec = rt.spec(artifact).unwrap().clone();
        let t = spec.in_shapes[0][0];
        assert_eq!(spec.in_shapes[0][1], nb.block.c, "{artifact} vs {label}");
        assert_eq!(spec.in_shapes[1][1], nb.block.k);

        // One input stream, two execution paths.
        let mut rng = Pcg64::seeded(9);
        let xs: Vec<Vec<f32>> = (0..t)
            .map(|_| (0..nb.block.c).map(|_| rng.next_normal() as f32).collect())
            .collect();

        // Path 1: PJRT (AOT JAX/Pallas artifact).
        let flat_x: Vec<f32> = xs.iter().flatten().copied().collect();
        let w = nb.block.dense_weights();
        let mask = nb.block.mask_f32();
        let y_pjrt = rt.execute(artifact, &[&flat_x, &w, &mask]).unwrap();

        // Path 2: SparseMap mapping + cycle-accurate simulation.
        let out = map_block(&nb.block, &cgra, &opts).unwrap();
        let res = simulate(&out.mapping, &nb.block, &cgra, &xs).unwrap();

        for (i, row) in res.outputs.iter().enumerate() {
            for (kr, &got) in row.iter().enumerate() {
                let want = y_pjrt[i * nb.block.k + kr];
                assert!(
                    (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "{label} iter {i} kernel {kr}: sim {got} vs pjrt {want}"
                );
            }
        }
    }
}

#[test]
fn artifact_shapes_cover_paper_blocks() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    for name in ["sb_c4k6", "sb_c6k6", "sb_c8k8", "conv_l1_c4k6_16x16", "conv_l2_c6k8_16x16"] {
        assert!(rt.spec(name).is_some(), "missing artifact {name}");
    }
}
