//! Golden mapping snapshots: the full SparseMap pipeline is deterministic,
//! so the `(II, COPs, MCIDs)` triple plus a placement fingerprint of every
//! paper block is pinned to a committed snapshot file. Any mapper change
//! that shifts a result — scheduler, router, conflict graph, SBTS solver,
//! cost model — fails this test loudly instead of drifting silently.
//!
//! Snapshot file: `rust/tests/golden_mappings.txt`, one
//! `label ii cops mcids placements=<hex fnv64>` line per block.
//!
//! * First run (file absent): the snapshot is written and the test passes
//!   with a loud "bootstrapped — commit it" notice.
//! * Intentional change: re-bless with `SPARSEMAP_BLESS=1 cargo test`,
//!   review the diff, commit the updated file alongside the change.

use sparsemap::arch::StreamingCgra;
use sparsemap::bind::{Mapping, Placement};
use sparsemap::mapper::{map_block, map_bundle, MapOutcome, MapperOptions};
use sparsemap::sim::{execute_plan_batch, simulate_fused_batch, ExecPlan, MemberSegment};
use sparsemap::sparse::gen::{fused3_bundle, paper_blocks, wide_blocks};
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden_mappings.txt")
}

/// FNV-1a 64 ([`sparsemap::util::Fnv64`]) over the mapping's II +
/// placement list — platform-independent and order-stable, so the
/// fingerprint moves iff a placement moves.
fn fingerprint(m: &Mapping) -> u64 {
    let mut h = sparsemap::util::Fnv64::new();
    h.eat_u64(m.ii as u64);
    for p in &m.placements {
        let (tag, x, y) = match *p {
            Placement::InputBus(i) => (1u8, i, 0),
            Placement::OutputBus(i) => (2u8, i, 0),
            Placement::Pe(pe) => (3u8, pe.row, pe.col),
        };
        h.eat(tag);
        h.eat_u64(x as u64);
        h.eat_u64(y as u64);
    }
    h.finish()
}

/// Cross-check the two simulation backends on a pinned mapping: the
/// compiled plan must report exactly the interpreter's pass cycles (the
/// full bit-identity contract lives in `tests/sim_equivalence.rs`; this
/// keeps the pinned golden mappings themselves covered by both backends).
fn assert_plan_cycles_match(out: &MapOutcome, blocks: &[&SparseBlock], label: &str) {
    let cgra = StreamingCgra::paper_default();
    let plan = ExecPlan::for_outcome(out, &cgra)
        .unwrap_or_else(|e| panic!("{label}: plan compile: {e}"));
    let streams: Vec<Vec<Vec<f32>>> = blocks
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let mut rng = Pcg64::seeded(7 + i as u64);
            (0..4).map(|_| (0..b.c).map(|_| rng.next_normal() as f32).collect()).collect()
        })
        .collect();
    let batches: Vec<Vec<MemberSegment<'_>>> = blocks
        .iter()
        .zip(&streams)
        .map(|(b, xs)| vec![MemberSegment { block: b, xs }])
        .collect();
    let compiled = execute_plan_batch(&plan, blocks, &batches)
        .unwrap_or_else(|e| panic!("{label}: compiled execution: {e}"));
    let interp = simulate_fused_batch(&out.mapping, &out.tags, blocks, &cgra, &batches)
        .unwrap_or_else(|e| panic!("{label}: interpreter: {e}"));
    assert_eq!(
        compiled.cycles, interp.cycles,
        "{label}: compiled and interpreter cycle counts diverge on a pinned mapping"
    );
}

fn render_snapshot() -> String {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    let mut out = String::new();
    for nb in paper_blocks() {
        let outcome = map_block(&nb.block, &cgra, &opts)
            .unwrap_or_else(|e| panic!("{}: paper block must map: {e}", nb.label));
        let m = &outcome.mapping;
        m.verify(&cgra).unwrap();
        out.push_str(&format!(
            "{} ii={} cops={} mcids={} placements={:016x}\n",
            nb.label,
            m.ii,
            m.cops(),
            m.mcids(),
            fingerprint(m)
        ));
        assert_plan_cycles_match(&outcome, &[&nb.block], nb.label);
    }
    // One wide-kernel-axis entry (k = 128 > the retired u64 mask width),
    // pinned at the shared wide operating point (`MapperOptions::wide()`):
    // its II slack and SBTS budget are part of the snapshot contract —
    // retuning `wide()` re-blesses this line.
    let wide_opts = MapperOptions::wide();
    let wide = wide_blocks()
        .into_iter()
        .find(|b| b.name == "wide_k128")
        .expect("wide_k128 generator");
    let wide_outcome = map_block(&wide, &cgra, &wide_opts)
        .unwrap_or_else(|e| panic!("wide_k128: wide block must map: {e}"));
    let m = &wide_outcome.mapping;
    m.verify(&cgra).unwrap();
    out.push_str(&format!(
        "wide_k128 ii={} cops={} mcids={} placements={:016x}\n",
        m.ii,
        m.cops(),
        m.mcids(),
        fingerprint(m)
    ));
    assert_plan_cycles_match(&wide_outcome, &[&wide], "wide_k128");
    // The canonical fused bundle (the three c = 4 paper blocks on one
    // fabric configuration) at the shared fused operating point
    // (`MapperOptions::fused()`). `per_block` pins each member's
    // cops/mcids — inside a bundle these equal the member's solo schedule
    // at the winning attempt (tests/fusion_equivalence.rs), so a drift
    // here means the fusion composition changed.
    let bundle = fused3_bundle();
    let fused = map_bundle(&bundle, &cgra, &MapperOptions::fused())
        .unwrap_or_else(|e| panic!("fused3: canonical bundle must map: {e}"));
    fused.mapping.verify(&cgra).unwrap();
    let per_block: Vec<String> = fused
        .per_block_stats()
        .iter()
        .map(|s| format!("{}/{}", s.cops, s.mcids))
        .collect();
    out.push_str(&format!(
        "fused3 ii={} cops={} mcids={} per_block={} placements={:016x}\n",
        fused.mapping.ii,
        fused.mapping.cops(),
        fused.mapping.mcids(),
        per_block.join(","),
        fingerprint(&fused.mapping)
    ));
    let members: Vec<&SparseBlock> = bundle.blocks.iter().map(|b| b.as_ref()).collect();
    assert_plan_cycles_match(&fused, &members, "fused3");
    out
}

#[test]
fn golden_mappings_match_snapshot() {
    let actual = render_snapshot();
    let path = golden_path();
    let bless = std::env::var("SPARSEMAP_BLESS").is_ok_and(|v| v == "1");
    if bless || !path.exists() {
        // On GitHub runners a missing snapshot means it was never
        // committed — bootstrapping there would silently disable the
        // check on every (fresh-checkout) run, so fail loudly instead.
        assert!(
            bless || std::env::var("GITHUB_ACTIONS").is_err(),
            "golden snapshot {} is not committed — run the test suite in a \
             toolchain-equipped checkout and commit the bootstrapped file",
            path.display()
        );
        std::fs::write(&path, &actual).expect("write golden snapshot");
        eprintln!(
            "golden_mappings: {} snapshot at {} — review and commit it:\n{actual}",
            if bless { "re-blessed" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        actual, want,
        "paper-block mappings shifted from the committed snapshot at {}.\n\
         If this change is intentional, re-bless with `SPARSEMAP_BLESS=1 \
         cargo test golden` and commit the updated file; otherwise a mapper \
         change silently altered results.",
        path.display()
    );
}
