//! Property-based tests (seeded mini-proptest, `util::proptest`) over the
//! coordinator-side invariants: s-DFG structure, schedule constraints,
//! binding legality and functional equivalence with the reference forward
//! pass.

use sparsemap::arch::StreamingCgra;
use sparsemap::config::Techniques;
use sparsemap::dfg::analysis::mii;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::dfg::{EdgeKind, NodeKind};
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sched::sparsemap::schedule_at;
use sparsemap::sim::simulate;
use sparsemap::sparse::gen::random_block;
use sparsemap::util::proptest::check;
use sparsemap::util::rng::Pcg64;

fn arb_block(rng: &mut Pcg64) -> sparsemap::sparse::SparseBlock {
    let c = 2 + rng.index(7);
    let k = 2 + rng.index(7);
    let p = 0.2 + 0.5 * rng.next_f64();
    random_block("prop", c, k, p, rng.next_u64())
}

#[test]
fn prop_sdfg_structure_invariants() {
    check("sdfg structure", 150, |rng| {
        let b = arb_block(rng);
        let (g, _) = build_sdfg(&b);
        g.validate().unwrap();
        // Node-count identities (DESIGN.md): |V_M| = nnz, |V_A| = nnz - k'.
        let f = b.features();
        let muls = g.nodes().filter(|&v| matches!(g.kind(v), NodeKind::Mul { .. })).count();
        assert_eq!(muls, f.nnz);
        assert_eq!(g.v_op().len(), f.v_op);
        assert_eq!(g.reads().len(), f.v_r);
        assert_eq!(g.writes().len(), f.v_w);
    });
}

#[test]
fn prop_schedule_respects_all_constraints() {
    let cgra = StreamingCgra::paper_default();
    check("schedule constraints", 80, |rng| {
        let b = arb_block(rng);
        let (g, _) = build_sdfg(&b);
        let base = mii(&g, &cgra);
        for ii in base..base + 3 {
            if let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), ii) {
                // verify() re-checks §3.2 (1)-(2) from first principles.
                s.verify(&cgra).unwrap();
                // Input deps never stretch: t(mul) == t(read).
                for e in s.g.edges() {
                    if e.kind == EdgeKind::Input {
                        assert_eq!(s.t[e.dst], s.t[e.src]);
                    }
                }
                return;
            }
        }
        // Not all random blocks are schedulable within the slack — fine.
    });
}

#[test]
fn prop_mapping_is_legal_and_functional() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    check("mapping legality + functional equivalence", 40, |rng| {
        let b = arb_block(rng);
        let Ok(out) = map_block(&b, &cgra, &opts) else { return };
        out.mapping.verify(&cgra).unwrap();
        // Functional equivalence on a random stream.
        let xs: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..b.c).map(|_| rng.next_normal() as f32).collect())
            .collect();
        let res = simulate(&out.mapping, &b, &cgra, &xs).unwrap();
        for (x, y) in xs.iter().zip(&res.outputs) {
            let want = b.forward(x);
            for (a, w) in y.iter().zip(&want) {
                assert!((a - w).abs() <= 1e-4 * (1.0 + w.abs()), "{a} vs {w}");
            }
        }
    });
}

#[test]
fn prop_mcid_count_invariant_under_ii() {
    // MCIDs never include distance-1 edges, and every counted MCID has a
    // consistent route (Bus for cop-sourced, else LRF/GRF).
    let cgra = StreamingCgra::paper_default();
    check("mcid routing consistency", 60, |rng| {
        let b = arb_block(rng);
        let (g, _) = build_sdfg(&b);
        let base = mii(&g, &cgra);
        let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), base + 1) else { return };
        let Ok(plan) = sparsemap::bind::route::preallocate(&s, &cgra) else { return };
        for (idx, e) in s.g.edges().iter().enumerate() {
            if e.kind != EdgeKind::Internal {
                assert!(plan.route(idx).is_none());
                continue;
            }
            let dist = s.t[e.dst] - s.t[e.src];
            assert!(dist >= 1);
            let route = plan.route(idx).expect("internal routed");
            use sparsemap::bind::Route;
            if matches!(s.g.kind(e.src), NodeKind::Cop { .. }) {
                assert_eq!(route, Route::Bus, "cop deps ride the cached bus");
            } else if dist == 1 {
                assert_eq!(route, Route::Bus);
            } else if s.m(e.src) == s.m(e.dst) {
                assert_eq!(route, Route::Grf, "same-modulo MCID forced to GRF");
            }
        }
    });
}

#[test]
fn prop_scratch_pool_reuse_is_behavior_neutral() {
    // One ScratchPool dragged across random blocks and IIs must produce
    // exactly the mappings fresh pools produce — reuse recycles
    // allocations, never state.
    let cgra = StreamingCgra::paper_default();
    check("scratch pool reuse", 25, |rng| {
        use sparsemap::bind::{bind, bind_with, ScratchPool};
        let mut pool = ScratchPool::new();
        for _ in 0..3 {
            let b = arb_block(rng);
            let (g, _) = build_sdfg(&b);
            let base = mii(&g, &cgra);
            let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), base + 1) else { continue };
            let seed = rng.next_u64();
            let reused = bind_with(&s, &cgra, 8_000, seed, &mut pool);
            let fresh = bind(&s, &cgra, 8_000, seed);
            match (reused, fresh) {
                (Ok(a), Ok(b2)) => {
                    assert_eq!(a.placements, b2.placements, "{}", b.name);
                    assert_eq!(a.plan_routes, b2.plan_routes);
                    assert_eq!(a.mis_iterations, b2.mis_iterations);
                }
                (Err(_), Err(_)) => {}
                (a, b2) => panic!(
                    "{}: reuse changed outcome: reused ok={} fresh ok={}",
                    b.name,
                    a.is_ok(),
                    b2.is_ok()
                ),
            }
        }
    });
}

#[test]
fn prop_incremental_hot_nodes_match_naive() {
    // Random walk over assignments: after every detach/reassign/attach the
    // incrementally tracked hot-node set must equal the from-scratch
    // recomputation, and the incremental cost must equal a fresh reset.
    use sparsemap::bind::{conflict, route, BusCostModel, Route, SecondaryCost};
    let cgra = StreamingCgra::paper_default();
    check("incremental hot nodes vs naive", 30, |rng| {
        let b = arb_block(rng);
        let (g, _) = build_sdfg(&b);
        let base = mii(&g, &cgra);
        let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), base + 1) else { return };
        let Ok(plan) = route::preallocate(&s, &cgra) else { return };
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<Option<Route>> =
            (0..s.g.edges().len()).map(|i| plan.route(i)).collect();

        let n_nodes = cg.of_node.len();
        let mut assign: Vec<usize> =
            (0..n_nodes).map(|v| cg.of_node[v][rng.index(cg.of_node[v].len())]).collect();
        let mut cost = BusCostModel::new(&s, &cg, &routes, &cgra);
        cost.reset(&assign);

        let mut buf = Vec::new();
        for _ in 0..40 {
            let v = rng.index(n_nodes);
            cost.detach(v, &assign);
            assign[v] = cg.of_node[v][rng.index(cg.of_node[v].len())];
            cost.attach(v, &assign);

            buf.clear();
            cost.hot_nodes_into(&assign, &mut buf);
            let naive = cost.hot_nodes_naive(&assign);
            assert_eq!(buf, naive, "{}: hot-node sets diverged", b.name);

            let mut fresh = BusCostModel::new(&s, &cg, &routes, &cgra);
            fresh.reset(&assign);
            assert_eq!(cost.total(), fresh.total(), "{}: cost drifted", b.name);
        }
    });
}

#[test]
fn prop_simulator_catches_time_corruption() {
    // Corrupting a node's schedule must break verify() or the simulation.
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap();
    check("failure injection", 25, |rng| {
        let b = arb_block(rng);
        let Ok(out) = map_block(&b, &cgra, &opts) else { return };
        let mut bad = out.mapping.clone();
        // Shift a random PE op's time by +1 (keeps vector sizes intact).
        let ops: Vec<usize> = bad
            .s
            .g
            .nodes()
            .filter(|&v| bad.s.g.kind(v).is_pe_op())
            .collect();
        let v = ops[rng.index(ops.len())];
        bad.s.t[v] += 1;
        let verify_fails = bad.s.verify(&cgra).is_err() || bad.verify(&cgra).is_err();
        let sim_fails = sparsemap::sim::simulate_and_check(&bad, &b, &cgra, 6, 1).is_err();
        assert!(
            verify_fails || sim_fails,
            "corrupted schedule must be detected (node {v})"
        );
    });
}
