//! The tentpole guarantee of the portfolio mapper: for any worker count,
//! `map_block` returns exactly the sequential order's answer — same II,
//! byte-identical placements and routes, same attempt history, same
//! first-attempt statistics.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapOutcome, MapperOptions};
use sparsemap::sparse::gen::paper_blocks;

fn assert_identical(label: &str, width: usize, seq: &MapOutcome, par: &MapOutcome) {
    assert_eq!(seq.mapping.ii, par.mapping.ii, "{label} w={width}: II");
    assert_eq!(
        seq.mapping.placements, par.mapping.placements,
        "{label} w={width}: placements"
    );
    assert_eq!(
        seq.mapping.plan_routes, par.mapping.plan_routes,
        "{label} w={width}: routes"
    );
    assert_eq!(seq.mapping.s.t, par.mapping.s.t, "{label} w={width}: schedule");
    assert_eq!(
        seq.mapping.mis_iterations, par.mapping.mis_iterations,
        "{label} w={width}: SBTS effort"
    );
    assert_eq!(seq.attempts, par.attempts, "{label} w={width}: attempt history");
    assert_eq!(seq.mii, par.mii, "{label} w={width}: MII");
    assert_eq!(seq.first_attempt.ii0, par.first_attempt.ii0, "{label} w={width}: II0");
    assert_eq!(seq.first_attempt.cops, par.first_attempt.cops, "{label} w={width}: |C|0");
    assert_eq!(seq.first_attempt.mcids, par.first_attempt.mcids, "{label} w={width}: |M|0");
    assert_eq!(
        seq.first_attempt.success, par.first_attempt.success,
        "{label} w={width}: first success"
    );
}

#[test]
fn portfolio_is_byte_identical_to_sequential_for_all_paper_blocks() {
    let cgra = StreamingCgra::paper_default();
    for (i, nb) in paper_blocks().iter().enumerate() {
        let seq = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(1))
            .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
        // Width 4 everywhere; an extra width-2 pass on the smallest and the
        // hardest block keeps the width axis covered without re-mapping
        // every block at every width.
        let widths: &[usize] = if i == 0 || i == 4 { &[2, 4] } else { &[4] };
        for &width in widths {
            let par = map_block(
                &nb.block,
                &cgra,
                &MapperOptions::sparsemap().with_parallelism(width),
            )
            .unwrap_or_else(|e| panic!("{} width {width}: {e}", nb.label));
            assert_identical(nb.label, width, &seq, &par);
        }
    }
}

#[test]
fn oversized_width_is_still_identical() {
    // More workers than lattice entries (and than cores) must change
    // nothing. block5 is the stress case: it needs II escalation, so the
    // portfolio actually cancels in-flight attempts.
    let cgra = StreamingCgra::paper_default();
    let nb = paper_blocks().into_iter().find(|n| n.label == "block5").unwrap();
    let seq = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(1))
        .unwrap();
    let par = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(64))
        .unwrap();
    assert_identical("block5", 64, &seq, &par);
}

#[test]
fn auto_width_is_identical_too() {
    // parallelism = 0 (the default everywhere) resolves to the hardware
    // width — same contract.
    let cgra = StreamingCgra::paper_default();
    let nb = &paper_blocks()[2];
    let seq = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(1))
        .unwrap();
    let auto = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
    assert_identical(nb.label, 0, &seq, &auto);
}
