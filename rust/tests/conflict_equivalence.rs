//! Differential suite locking the binding-stage rewrites to their retired
//! naive implementations (`sparsemap::bind::oracle`):
//!
//! * the bucketed conflict-graph build must produce **byte-identical**
//!   graphs to the all-pairs `O(nc²)` edge loop — candidates, `of_node`,
//!   and edge sets compared as sorted pair lists — over all 7 paper blocks
//!   at several IIs plus ≥100 randomized scheduled s-DFG instances;
//! * the dense slot-major bus cost model must track identical totals,
//!   per-bus claim multisets and hot-node sets as the `HashMap` model over
//!   randomized claim/release (detach/reassign/attach) sequences,
//!   including modulo-slot wraparound at the II boundary;
//! * with either cost model plugged into the SBTS solve, the trajectory —
//!   and therefore the final mapping — must be move-for-move identical.

use std::sync::atomic::{AtomicUsize, Ordering};

use sparsemap::arch::StreamingCgra;
use sparsemap::bind::mis::{solve_with_scratch, SolverScratch};
use sparsemap::bind::oracle::{build_naive, HashBusCostModel};
use sparsemap::bind::{
    bind, conflict, route, BucketScratch, BusCostModel, Candidate, ConflictGraph, Placement,
    Route, SecondaryCost,
};
use sparsemap::config::Techniques;
use sparsemap::dfg::analysis::mii;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::dfg::{EdgeKind, NodeKind, SDfg};
use sparsemap::sched::sparsemap::schedule_at;
use sparsemap::sched::ScheduledSDfg;
use sparsemap::sparse::gen::{paper_blocks, random_block};
use sparsemap::util::proptest::check;
use sparsemap::util::rng::Pcg64;

/// Edge set as a sorted list of candidate-index pairs `(a < b)` — the
/// canonical form both builds are compared in.
fn edge_list(cg: &ConflictGraph) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for (a, adj) in cg.adj.iter().enumerate() {
        for b in adj.iter() {
            if a < b {
                edges.push((a, b));
            }
        }
    }
    edges
}

fn assert_graphs_identical(fast: &ConflictGraph, slow: &ConflictGraph, label: &str) {
    assert_eq!(fast.candidates, slow.candidates, "{label}: candidate lists differ");
    assert_eq!(fast.of_node, slow.of_node, "{label}: of_node differs");
    assert_eq!(fast.num_nodes, slow.num_nodes, "{label}: num_nodes differs");
    assert_eq!(
        fast.adj.len(),
        slow.adj.len(),
        "{label}: adjacency table sizes differ"
    );
    assert_eq!(edge_list(fast), edge_list(slow), "{label}: edge sets differ");
}

/// A routable schedule for `(g, cgra)` at the lowest II in `[mii, mii+3)`,
/// if any.
fn routable_schedule(
    g: &SDfg,
    cgra: &StreamingCgra,
) -> Option<(ScheduledSDfg, route::RoutePlan)> {
    let base = mii(g, cgra);
    (base..base + 3).find_map(|ii| {
        let s = schedule_at(g, cgra, Techniques::all(), ii).ok()?;
        let plan = route::preallocate(&s, cgra).ok()?;
        Some((s, plan))
    })
}

#[test]
fn bucketed_build_matches_naive_on_paper_blocks() {
    let cgra = StreamingCgra::paper_default();
    let mut scratch = ConflictGraph::empty();
    let mut buckets = BucketScratch::new();
    let mut instances = 0usize;
    for nb in paper_blocks() {
        let (g, _) = build_sdfg(&nb.block);
        let base = mii(&g, &cgra);
        for ii in base..base + 3 {
            let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), ii) else { continue };
            let Ok(plan) = route::preallocate(&s, &cgra) else { continue };
            // One reused scratch across every block and II — the exact
            // shape the portfolio mapper drives.
            conflict::build_into(&s, &cgra, &plan, &mut scratch, &mut buckets);
            let slow = build_naive(&s, &cgra, &plan);
            assert_graphs_identical(&scratch, &slow, &format!("{} II={ii}", nb.label));
            instances += 1;
        }
    }
    assert!(instances >= 7, "only {instances} paper-block instances compared");
}

#[test]
fn prop_bucketed_build_matches_naive_on_random_schedules() {
    let cgra = StreamingCgra::paper_default();
    let compared = AtomicUsize::new(0);
    check("bucketed conflict build ≡ all-pairs oracle", 120, |rng| {
        // Small-to-medium blocks keep the O(nc²) oracle affordable in
        // debug builds while still covering every node/edge shape.
        let c = 2 + rng.index(5);
        let k = 2 + rng.index(5);
        let p = 0.2 + 0.6 * rng.next_f64();
        let b = random_block("diff", c, k, p, rng.next_u64());
        let (g, _) = build_sdfg(&b);
        let base = mii(&g, &cgra);
        let mut scratch = ConflictGraph::empty();
        let mut buckets = BucketScratch::new();
        // Vary the II per instance — bucket tables must resize correctly
        // when the same scratch is dragged across IIs.
        let mut done = 0;
        for ii in base..base + 3 {
            if done == 2 {
                break;
            }
            let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), ii) else { continue };
            let Ok(plan) = route::preallocate(&s, &cgra) else { continue };
            conflict::build_into(&s, &cgra, &plan, &mut scratch, &mut buckets);
            let slow = build_naive(&s, &cgra, &plan);
            assert_graphs_identical(&scratch, &slow, &format!("{} II={ii}", b.name));
            done += 1;
            compared.fetch_add(1, Ordering::Relaxed);
        }
    });
    let n = compared.load(Ordering::Relaxed);
    assert!(n >= 100, "only {n} randomized instances compared (want ≥ 100)");
}

/// Both cost models, reset to the same assignment; every comparison the
/// suite makes between them.
fn assert_models_agree(
    dense: &BusCostModel,
    hash: &HashBusCostModel,
    assign: &[usize],
    label: &str,
) {
    assert_eq!(dense.total(), hash.total(), "{label}: totals diverged");
    assert_eq!(
        dense.claims_snapshot(),
        hash.claims_snapshot(),
        "{label}: claim states diverged"
    );
    let (mut a, mut b) = (Vec::new(), Vec::new());
    dense.hot_nodes_into(assign, &mut a);
    hash.hot_nodes_into(assign, &mut b);
    assert_eq!(a, b, "{label}: hot-node sets diverged");
}

#[test]
fn prop_dense_bus_cost_matches_hash_oracle() {
    let cgra = StreamingCgra::paper_default();
    let walked = AtomicUsize::new(0);
    check("dense bus cost ≡ HashMap oracle", 50, |rng| {
        let c = 2 + rng.index(7);
        let k = 2 + rng.index(7);
        let p = 0.2 + 0.6 * rng.next_f64();
        let b = random_block("cost", c, k, p, rng.next_u64());
        let (g, _) = build_sdfg(&b);
        let Some((s, plan)) = routable_schedule(&g, &cgra) else { return };
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<Option<Route>> =
            (0..s.g.edges().len()).map(|i| plan.route(i)).collect();

        let n_nodes = cg.of_node.len();
        let mut assign: Vec<usize> =
            (0..n_nodes).map(|v| cg.of_node[v][rng.index(cg.of_node[v].len())]).collect();
        let mut dense = BusCostModel::new(&s, &cg, &routes, &cgra);
        let mut hash = HashBusCostModel::new(&s, &cg, &routes);
        dense.reset(&assign);
        hash.reset(&assign);
        assert_models_agree(&dense, &hash, &assign, &b.name);

        // Random claim/release walk: detach, reassign, attach — with an
        // occasional mid-walk reset (the solver's restart path).
        for step in 0..50 {
            let v = rng.index(n_nodes);
            dense.detach(v, &assign);
            hash.detach(v, &assign);
            assign[v] = cg.of_node[v][rng.index(cg.of_node[v].len())];
            dense.attach(v, &assign);
            hash.attach(v, &assign);
            assert_models_agree(&dense, &hash, &assign, &format!("{} step {step}", b.name));
            if step % 17 == 16 {
                dense.reset(&assign);
                hash.reset(&assign);
                assert_models_agree(&dense, &hash, &assign, &format!("{} reset {step}", b.name));
            }
        }
        walked.fetch_add(1, Ordering::Relaxed);
    });
    assert!(
        walked.load(Ordering::Relaxed) >= 25,
        "too few cost-model walks exercised"
    );
}

#[test]
fn dense_bus_cost_handles_ii_wraparound() {
    // Hand-built schedule whose late nodes wrap past the II boundary:
    // t(a) = 3, t(w) = 4 at II = 2, so the output claim lands at modulo
    // slot 0 and one mul→add MCID is GRF-forced (same modulo slot).
    let cgra = StreamingCgra::paper_default();
    let mut g = SDfg::new("wrap");
    let r0 = g.add_node(NodeKind::Read { ch: 0, replica: 0 });
    let r1 = g.add_node(NodeKind::Read { ch: 1, replica: 0 });
    let m0 = g.add_node(NodeKind::Mul { ch: 0, kr: 0 });
    let m1 = g.add_node(NodeKind::Mul { ch: 1, kr: 0 });
    let a = g.add_node(NodeKind::Add { kr: 0 });
    let w = g.add_node(NodeKind::Write { kr: 0 });
    g.add_edge(r0, m0, EdgeKind::Input);
    g.add_edge(r1, m1, EdgeKind::Input);
    g.add_edge(m0, a, EdgeKind::Internal);
    g.add_edge(m1, a, EdgeKind::Internal);
    g.add_edge(a, w, EdgeKind::Output);
    let s = ScheduledSDfg { g, ii: 2, t: vec![0, 1, 0, 1, 3, 4] };
    s.verify(&cgra).unwrap();
    let plan = route::preallocate(&s, &cgra).unwrap();
    assert_eq!(plan.grf_count(), 1, "m1→a is same-modulo and GRF-forced");
    assert_eq!(plan.lrf_count(), 1, "m0→a crosses slots and takes the LRF");

    let cg = conflict::build(&s, &cgra, &plan);
    assert_graphs_identical(&cg, &build_naive(&s, &cgra, &plan), "wrap");

    let routes: Vec<Option<Route>> = (0..s.g.edges().len()).map(|i| plan.route(i)).collect();
    let mut rng = Pcg64::seeded(0x77ab_5eed);
    let n_nodes = cg.of_node.len();
    let mut assign: Vec<usize> = (0..n_nodes).map(|v| cg.of_node[v][0]).collect();
    let mut dense = BusCostModel::new(&s, &cg, &routes, &cgra);
    let mut hash = HashBusCostModel::new(&s, &cg, &routes);
    dense.reset(&assign);
    hash.reset(&assign);
    assert_models_agree(&dense, &hash, &assign, "wrap init");
    // The write's output claim must have wrapped to slot 0 (t(w) = 4).
    assert!(
        dense
            .claims_snapshot()
            .iter()
            .any(|(bus, _)| matches!(bus, sparsemap::bind::BusAt::Row { slot: 0, .. })),
        "expected a slot-0 row-bus claim from the wrapped write"
    );
    for step in 0..120 {
        let v = rng.index(n_nodes);
        dense.detach(v, &assign);
        hash.detach(v, &assign);
        assign[v] = cg.of_node[v][rng.index(cg.of_node[v].len())];
        dense.attach(v, &assign);
        hash.attach(v, &assign);
        assert_models_agree(&dense, &hash, &assign, &format!("wrap step {step}"));
    }
}

#[test]
fn prop_incremental_hot_index_matches_oracles_at_inflated_ii() {
    // The dense model maintains its hot-bus set incrementally on every
    // claim/release (PR 4) instead of rescanning all II × (n + m) bus
    // states per SBTS iteration. Inflated IIs are where a stale index
    // would hide (a huge, mostly-cold bus array — the wide-block regime);
    // walk randomized reassignments there and compare the incremental set
    // against the from-scratch recompute (hot_nodes_naive) and the
    // HashMap oracle on every step.
    let cgra = StreamingCgra::paper_default();
    let walked = AtomicUsize::new(0);
    check("incremental hot index ≡ naive recompute ≡ hash oracle", 40, |rng| {
        let c = 2 + rng.index(6);
        let k = 2 + rng.index(6);
        let p = 0.2 + 0.6 * rng.next_f64();
        let b = random_block("hot", c, k, p, rng.next_u64());
        let (g, _) = build_sdfg(&b);
        let ii = mii(&g, &cgra) + 4 + rng.index(8);
        let Ok(s) = schedule_at(&g, &cgra, Techniques::all(), ii) else { return };
        let Ok(plan) = route::preallocate(&s, &cgra) else { return };
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<Option<Route>> =
            (0..s.g.edges().len()).map(|i| plan.route(i)).collect();

        let n_nodes = cg.of_node.len();
        let mut assign: Vec<usize> =
            (0..n_nodes).map(|v| cg.of_node[v][rng.index(cg.of_node[v].len())]).collect();
        let mut dense = BusCostModel::new(&s, &cg, &routes, &cgra);
        let mut hash = HashBusCostModel::new(&s, &cg, &routes);
        dense.reset(&assign);
        hash.reset(&assign);
        for step in 0..60 {
            let v = rng.index(n_nodes);
            dense.detach(v, &assign);
            hash.detach(v, &assign);
            assign[v] = cg.of_node[v][rng.index(cg.of_node[v].len())];
            dense.attach(v, &assign);
            hash.attach(v, &assign);
            let mut inc = Vec::new();
            dense.hot_nodes_into(&assign, &mut inc);
            assert_eq!(
                inc,
                dense.hot_nodes_naive(&assign),
                "II={ii} step {step}: incremental hot set ≠ naive recompute"
            );
            let mut oracle_hot = Vec::new();
            hash.hot_nodes_into(&assign, &mut oracle_hot);
            assert_eq!(
                inc, oracle_hot,
                "II={ii} step {step}: incremental hot set ≠ hash oracle"
            );
        }
        walked.fetch_add(1, Ordering::Relaxed);
    });
    assert!(walked.load(Ordering::Relaxed) >= 20, "too few hot-index walks exercised");
}

#[test]
fn sbts_trajectory_identical_under_either_cost_model() {
    // The solve is a pure function of (cg, seed, cost); with behaviorally
    // identical cost models the whole trajectory — iterations included —
    // must match, which is what makes final mappings byte-identical.
    let cgra = StreamingCgra::paper_default();
    for nb in paper_blocks() {
        let (g, _) = build_sdfg(&nb.block);
        let Some((s, plan)) = routable_schedule(&g, &cgra) else {
            panic!("{}: no routable schedule", nb.label);
        };
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<Option<Route>> =
            (0..s.g.edges().len()).map(|i| plan.route(i)).collect();
        for seed in [1u64, 42, 1337] {
            let mut dense = BusCostModel::new(&s, &cg, &routes, &cgra);
            let mut hash = HashBusCostModel::new(&s, &cg, &routes);
            let a = solve_with_scratch(&cg, 30_000, seed, &mut dense, &mut SolverScratch::new());
            let b = solve_with_scratch(&cg, 30_000, seed, &mut hash, &mut SolverScratch::new());
            assert_eq!(a.assignment, b.assignment, "{} seed {seed}", nb.label);
            assert_eq!(a.chosen, b.chosen, "{} seed {seed}", nb.label);
            assert_eq!(a.clean, b.clean, "{} seed {seed}", nb.label);
            assert_eq!(a.iterations, b.iterations, "{} seed {seed}", nb.label);
        }
    }
}

/// bind_with's attempt loop, composed from the oracles: naive all-pairs
/// conflict graph + HashMap cost model + the same seeds, attempt count and
/// final verification.
fn oracle_bind(
    s: &ScheduledSDfg,
    cgra: &StreamingCgra,
    mis_iterations: usize,
    seed: u64,
) -> Option<(Vec<Placement>, usize)> {
    let plan = route::preallocate(s, cgra).ok()?;
    let cg = build_naive(s, cgra, &plan);
    let routes: Vec<Option<Route>> = (0..s.g.edges().len()).map(|i| plan.route(i)).collect();
    let mut cost = HashBusCostModel::new(s, &cg, &routes);
    let mut spent = 0usize;
    for attempt in 0..3u64 {
        let res = solve_with_scratch(
            &cg,
            mis_iterations,
            seed.wrapping_add(attempt * 0x9e37),
            &mut cost,
            &mut SolverScratch::new(),
        );
        spent += res.iterations;
        if !res.clean {
            continue;
        }
        let placements: Vec<Placement> = res
            .assignment
            .iter()
            .map(|&c| match cg.candidates[c] {
                Candidate::Read { ibus, .. } => Placement::InputBus(ibus),
                Candidate::Write { obus, .. } => Placement::OutputBus(obus),
                Candidate::Op { pe, .. } => Placement::Pe(pe),
            })
            .collect();
        // Mirror bind_with's final verification step.
        let mapping = sparsemap::bind::Mapping {
            s: s.clone(),
            placements,
            plan_routes: routes.clone(),
            mis_iterations: spent,
            ii: s.ii,
        };
        mapping.verify(cgra).ok()?;
        return Some((mapping.placements, spent));
    }
    None
}

#[test]
fn production_bind_matches_naive_pipeline_end_to_end() {
    // bind() (bucketed build + dense cost) vs the same attempt loop
    // composed from the oracles — placements and iteration counts must be
    // byte-identical on every paper block.
    let cgra = StreamingCgra::paper_default();
    let (mis_iterations, seed) = (60_000usize, 42u64);
    for nb in paper_blocks() {
        let (g, _) = build_sdfg(&nb.block);
        let s = match schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let fast = bind(&s, &cgra, mis_iterations, seed);
        let naive = oracle_bind(&s, &cgra, mis_iterations, seed);

        match (fast, naive) {
            (Ok(m), Some((placements, spent))) => {
                assert_eq!(m.placements, placements, "{}: placements differ", nb.label);
                assert_eq!(m.mis_iterations, spent, "{}: iteration counts differ", nb.label);
            }
            (Err(_), None) => {}
            (fast, naive) => panic!(
                "{}: outcome diverged — production ok={}, oracle ok={}",
                nb.label,
                fast.is_ok(),
                naive.is_some()
            ),
        }
    }
}
