//! Integration suite for the sharded serving tier: bit-identical outputs
//! across shard/worker/batching topologies, cross-session window
//! formation as a pure function of the global enqueue/cancel order, warm
//! starts from the on-disk manifest, and (under the `failpoints` feature)
//! shard-level fault isolation — one dead pool drains its own queue while
//! sibling shards keep serving.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, ServeError, Ticket};
use sparsemap::sparse::fuse::FusedBundle;
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

#[cfg(feature = "failpoints")]
use sparsemap::util::failpoint::{configure, FailScenario, FaultKind, Trigger};

/// Failpoint state is process-global and cargo runs this file's tests
/// concurrently: under the `failpoints` feature EVERY test (armed or not)
/// holds a `FailScenario`, which serializes them and guarantees no armed
/// site leaks into an unsuspecting test. Without the feature it is free.
#[cfg(feature = "failpoints")]
fn scenario() -> FailScenario {
    FailScenario::setup()
}

/// No-op stand-in guard when failpoints are compiled out.
#[cfg(not(feature = "failpoints"))]
struct FailScenario;

#[cfg(not(feature = "failpoints"))]
fn scenario() -> FailScenario {
    FailScenario
}

fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
    Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
}

fn tiny_members() -> Vec<Arc<SparseBlock>> {
    vec![
        tiny("f1", 2, 2, vec![true, false, true, true]),
        tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
        tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
    ]
}

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

fn base_cfg() -> SparsemapConfig {
    let mut cfg = SparsemapConfig::default();
    cfg.queue_depth = 8;
    cfg.parallelism = 1;
    cfg.mis_iterations = 20_000;
    cfg
}

/// Bounded wait: a ticket that does not resolve within the bound is a
/// hang — exactly the bug class this suite exists to catch.
fn must_resolve(t: &mut Ticket) -> Result<(), ServeError> {
    t.wait_timeout(Duration::from_secs(60))
        .expect("ticket must resolve, not hang")
        .map(|_| ())
}

/// Poll the worker-side window/job counters up to a bound without
/// touching any ticket (waiting a ticket seals its window, which would
/// mask the enqueue-order-driven seal these tests assert).
fn wait_for_counters(coord: &Coordinator, windows: u64, jobs: u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let m = coord.metrics.snapshot();
        if m.windows >= windows && m.jobs >= jobs {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "counters stuck at windows={} jobs={} (want {windows}/{jobs})",
            m.windows,
            m.jobs
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Run one fixed multi-session traffic trace (fused members round-robin
/// with two solo blocks, interleaved over three sessions) against a
/// pinned topology and return every request's outputs as raw bits, in
/// global enqueue order.
fn run_trace(shards: usize, workers: usize, window_requests: usize) -> Vec<Vec<Vec<u32>>> {
    let mut cfg = base_cfg();
    cfg.workers = workers;
    cfg.batch_window_requests = window_requests;
    let coord = Coordinator::with_shard_count(&cfg, shards);
    let members = tiny_members();
    coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
    let solos = vec![
        tiny("s1", 2, 2, vec![true, true, true, false]),
        tiny("s2", 3, 3, vec![true, false, true, false, true, true, true, false, true]),
    ];
    let traffic: Vec<Arc<SparseBlock>> = members.iter().chain(solos.iter()).cloned().collect();

    let mut sessions: Vec<_> = (0..3).map(|_| coord.session()).collect();
    let mut tickets = Vec::new();
    for i in 0..20usize {
        let block = &traffic[i % traffic.len()];
        let xs = stream_for(block, 1 + i % 3, i as u64);
        tickets.push(sessions[i % sessions.len()].enqueue(Arc::clone(block), xs));
    }
    for s in &mut sessions {
        s.flush();
    }
    tickets
        .into_iter()
        .map(|t| {
            let r = t.wait().expect("traced request ok");
            r.outputs
                .iter()
                .map(|row| row.iter().map(|v| v.to_bits()).collect())
                .collect()
        })
        .collect()
}

#[test]
fn outputs_bit_identical_across_shard_worker_and_batching_knobs() {
    let _s = scenario();
    // The determinism contract: serving output is a pure function of the
    // request trace — shard count, worker count and window knobs shape
    // latency and window composition, never bits.
    let reference = run_trace(1, 1, 2);
    let topologies = [(1, 2, 2), (2, 1, 2), (2, 2, 2), (3, 2, 4), (2, 2, 1), (4, 1, 8)];
    for (shards, workers, window) in topologies {
        let got = run_trace(shards, workers, window);
        assert_eq!(
            got, reference,
            "outputs diverged at shards={shards} workers={workers} window={window}"
        );
    }
}

#[test]
fn cross_session_window_forms_from_the_global_enqueue_order() {
    let _s = scenario();
    // Two sessions, two member requests each, interleaved: the window
    // fills from the GLOBAL stream and seals at 4 riders — no flush, no
    // wait, no timing involved.
    let run = || -> (u64, u64) {
        let mut cfg = base_cfg();
        cfg.workers = 2;
        cfg.batch_window_requests = 4;
        let coord = Coordinator::with_shard_count(&cfg, 2);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut s1 = coord.session();
        let mut s2 = coord.session();
        let mut tickets = Vec::new();
        for i in 0..4usize {
            let b = &members[i % members.len()];
            let xs = stream_for(b, 2, i as u64);
            tickets.push(if i % 2 == 0 {
                s1.enqueue(Arc::clone(b), xs)
            } else {
                s2.enqueue(Arc::clone(b), xs)
            });
        }
        // No flush, no wait (`wait` would seal the window itself): the
        // 4th enqueue alone must have sealed and dispatched it. Poll the
        // worker-side counters under a bound.
        wait_for_counters(&coord, 1, 4);
        for mut t in tickets {
            must_resolve(&mut t).expect("windowed request ok");
        }
        let m = coord.metrics.snapshot();
        (m.windows, m.jobs)
    };
    assert_eq!(run(), (1, 4), "four riders from two sessions → ONE window");
    assert_eq!(run(), (1, 4), "repeat runs form identical windows");
}

#[test]
fn cancellation_is_part_of_the_window_forming_order() {
    let _s = scenario();
    // Window contents are a pure function of the global enqueue/CANCEL
    // sequence: a dropped ticket withdraws its rider, so the window seals
    // only when four *live* riders are aboard.
    let run = || -> (u64, u64) {
        let mut cfg = base_cfg();
        cfg.workers = 2;
        cfg.batch_window_requests = 4;
        let coord = Coordinator::with_shard_count(&cfg, 2);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut s1 = coord.session();
        let mut s2 = coord.session();
        let t0 = s1.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 0));
        let dropped = s2.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 1));
        drop(dropped); // withdrawn: the window is back to 1 rider
        let t2 = s1.enqueue(Arc::clone(&members[2]), stream_for(&members[2], 2, 2));
        let t3 = s2.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 3));
        let t4 = s1.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 4));
        // The 5th enqueue is the 4th LIVE rider: it alone seals the
        // window — observed worker-side before any ticket is waited.
        wait_for_counters(&coord, 1, 4);
        for mut t in [t0, t2, t3, t4] {
            must_resolve(&mut t).expect("surviving rider ok");
        }
        let m = coord.metrics.snapshot();
        (m.windows, m.jobs)
    };
    assert_eq!(run(), (1, 4), "the cancelled rider never dispatches");
    assert_eq!(run(), (1, 4), "cancel-shaped windows are deterministic too");
}

#[test]
fn warm_start_prebuilds_registered_mappings_from_the_manifest() {
    let _s = scenario();
    let path = std::env::temp_dir()
        .join(format!("sparsemap-warmstart-{}.manifest", std::process::id()));
    let path_str = path.to_str().expect("utf8 temp path").to_string();
    let _ = std::fs::remove_file(&path);

    let mut cfg = base_cfg();
    cfg.workers = 1;
    cfg.warm_start_path = path_str;
    let members = tiny_members();
    let solo = tiny("warm", 2, 2, vec![true, false, true, true]);

    // First life: registrations persist to the manifest as they happen.
    {
        let coord = Coordinator::with_shard_count(&cfg, 2);
        coord.register_block(Arc::clone(&solo));
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let mut t = session.enqueue(Arc::clone(&solo), stream_for(&solo, 2, 1));
        must_resolve(&mut t).expect("first-life request ok");
        coord.shutdown();
    }
    assert!(path.exists(), "registration must write the manifest");

    // Second life: construction replays the manifest, pre-building the
    // solo and bundle mappings through the normal cache path — so the
    // first real requests are cache hits.
    {
        let coord = Coordinator::with_shard_count(&cfg, 2);
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 2, "solo + bundle pre-built at construction");
        let mut session = coord.session();
        let solo_r = session
            .enqueue(Arc::clone(&solo), stream_for(&solo, 2, 2))
            .wait()
            .expect("warm solo ok");
        assert!(!solo_r.mapped_fresh, "warm-started mapping serves as a cache hit");
        let xs = stream_for(&members[0], 2, 3);
        let member_t = session.enqueue(Arc::clone(&members[0]), xs);
        session.flush();
        let member_r = member_t.wait().expect("warm member ok");
        assert!(!member_r.mapped_fresh, "bundle mapping was pre-built too");
        assert_eq!(member_r.fused_members, 3, "manifest restored the bundle route");
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "no cold builds");
    }
    let _ = std::fs::remove_file(&path);
}

/// Satellite of the network-serving subsystem: a registered network
/// round-trips through the warm-start manifest. Its tile blocks (and any
/// bundles the fusion planner packed) ride their own manifest lines, so
/// the second life pre-builds every mapping at construction; the
/// `network` line restores the registry entry `enqueue_network` looks up
/// by name — and the restored network serves bit-identically to the
/// first life without a single cold build.
#[test]
fn warm_start_restores_registered_networks_from_the_manifest() {
    use sparsemap::model::NetworkGraph;
    use sparsemap::sparse::prune::synthetic_pruned_layer;

    let _s = scenario();
    let path = std::env::temp_dir()
        .join(format!("sparsemap-warmstart-net-{}.manifest", std::process::id()));
    let path_str = path.to_str().expect("utf8 temp path").to_string();
    let _ = std::fs::remove_file(&path);

    let mut cfg = base_cfg();
    cfg.workers = 1;
    cfg.warm_start_path = path_str;
    let layers = || {
        vec![
            synthetic_pruned_layer("wn1", 4, 6, 0.50, 81).unwrap(),
            synthetic_pruned_layer("wn2", 6, 4, 0.50, 82).unwrap(),
        ]
    };
    let x: Vec<f32> = (0..4).map(|i| 0.25 + i as f32 * 0.5).collect();

    // First life: register + serve once; registration writes the manifest.
    let first_bits: Vec<u32> = {
        let coord = Coordinator::with_shard_count(&cfg, 2);
        let net = NetworkGraph::from_layers("warmnet", layers()).unwrap();
        coord.register_network(net).expect("first-life registration ok");
        let session = coord.session();
        let res = session
            .enqueue_network("warmnet", &x)
            .unwrap()
            .wait()
            .expect("first-life network ok");
        coord.shutdown();
        res.outputs.iter().map(|v| v.to_bits()).collect()
    };
    assert!(path.exists(), "network registration must write the manifest");

    // Second life: the manifest restores the network and pre-builds its
    // tile mappings through the normal cache path.
    {
        let coord = Coordinator::with_shard_count(&cfg, 2);
        let restored = coord.network("warmnet").expect("manifest restored the network");
        assert_eq!(restored.stages.len(), 2, "both layers survive the round trip");
        let prebuilt = coord.metrics.snapshot().cache_misses;
        assert!(prebuilt > 0, "tile mappings pre-built at construction");
        let session = coord.session();
        let res = session
            .enqueue_network("warmnet", &x)
            .unwrap()
            .wait()
            .expect("second-life network ok");
        assert_eq!(res.layers.len(), 2, "per-layer attribution survives the round trip");
        let bits: Vec<u32> = res.outputs.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, first_bits, "restored network serves bit-identically");
        assert_eq!(
            coord.metrics.snapshot().cache_misses,
            prebuilt,
            "the warm life never cold-builds a network tile"
        );
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_reports_per_shard_counters() {
    let _s = scenario();
    let mut cfg = base_cfg();
    cfg.workers = 1;
    cfg.batch_window_requests = 2;
    let coord = Coordinator::with_shard_count(&cfg, 2);
    let members = tiny_members();
    coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
    let mut session = coord.session();
    let t0 = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 0));
    let t1 = session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 1));
    for mut t in [t0, t1] {
        must_resolve(&mut t).expect("windowed request ok");
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.shards.len(), 2, "one counter block per shard");
    assert_eq!(m.windows, 1);
    let per_shard: u64 = m.shards.iter().map(|s| s.windows).sum();
    assert_eq!(per_shard, 1, "the window is attributed to exactly one shard");
    let served = m.shards.iter().find(|s| s.windows == 1).expect("owning shard");
    assert!(
        served.queue_ns_p99 >= served.queue_ns_p50 && served.queue_ns_p50 > 0.0,
        "the owning shard observed the riders' queue spans"
    );
    let idle = m.shards.iter().find(|s| s.windows == 0).expect("idle shard");
    assert_eq!(idle.queue_ns_p50, 0.0, "the idle shard observed nothing");
}

#[cfg(feature = "failpoints")]
#[test]
fn one_dead_shard_pool_never_blocks_sibling_shards() {
    let _s = scenario();
    // Kill the first worker to pick up a job — hard, outside the per-job
    // catch_unwind — with a restart budget of zero: that shard's pool
    // dies for good and its supervisor drains the queue, while the
    // sibling shard keeps serving. Per-shard budgets are the isolation
    // boundary under test.
    configure("coordinator::worker_hard", FaultKind::Panic, Trigger::FirstN(1), 0);
    let mut cfg = base_cfg();
    cfg.workers = 1;
    cfg.restart_budget = 0;
    let coord = Coordinator::with_shard_count(&cfg, 2);
    // Two equal-demand blocks spread across both shards deterministically
    // (greedy assigner: first → shard 0, second → the empty shard 1).
    let block_a = tiny("victim", 2, 2, vec![true, false, true, true]);
    let block_b = tiny("survivor", 2, 2, vec![true, true, false, true]);
    let sid_a = coord.register_block(Arc::clone(&block_a));
    let sid_b = coord.register_block(Arc::clone(&block_b));
    assert_ne!(sid_a, sid_b, "equal-demand blocks must spread across shards");

    let mut session = coord.session();
    // Serialize the kill: the first pickup anywhere trips the failpoint,
    // so send the victim alone and wait for its WorkerGone before any
    // other traffic can race for the trigger.
    let mut victim = session.enqueue(Arc::clone(&block_a), stream_for(&block_a, 2, 0));
    match must_resolve(&mut victim) {
        Err(ServeError::WorkerGone) => {}
        other => panic!("expected WorkerGone aboard the dying worker, got {other:?}"),
    }

    // The dead shard's queue still resolves everything (supervisor
    // drain), and the sibling shard serves normally — every enqueued
    // ticket resolves, on both sides.
    let mut gone = 0;
    let mut ok = 0;
    for i in 0..4u64 {
        let block = if i % 2 == 0 { &block_a } else { &block_b };
        let mut t = session.enqueue(Arc::clone(block), stream_for(block, 2, 10 + i));
        match must_resolve(&mut t) {
            Ok(()) => ok += 1,
            Err(ServeError::WorkerGone) => gone += 1,
            Err(other) => panic!("unexpected error under shard death: {other:?}"),
        }
    }
    assert_eq!(gone, 2, "the dead shard drains its tickets as WorkerGone");
    assert_eq!(ok, 2, "the sibling shard serves its tickets");

    let m = coord.metrics.snapshot();
    assert_eq!(m.shards.len(), 2);
    assert_eq!(m.worker_restarts, 0, "budget 0: the pool was never respawned");
    assert!(m.shards[sid_b].queue_ns_p50 > 0.0, "the surviving shard served requests");
    assert_eq!(m.shards[sid_a].queue_ns_p50, 0.0, "the dead shard served nothing");
}
