//! Acceptance suite for model ingestion + whole-network pipeline serving:
//! a synthetic pruned ≥4-layer network (one wide_k128-class layer
//! included) loads through `cli ingest`, registers with the coordinator,
//! and serves end to end through `ServeSession::enqueue_network` —
//! bit-identical to the per-layer reference chain that serves every
//! partitioned tile solo through the plain session API with the same
//! gather/scatter, ~1e-3-close to the dense `NetworkGraph::forward`
//! chain, with per-layer cycle/COP/MCID attribution. The equivalence
//! matrix locks the pipeline bit-identical across shard counts and lane
//! widths (CI additionally runs this file under `SPARSEMAP_SHARDS=2`).

use std::sync::Arc;

use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, NetworkResult};
use sparsemap::mapper::MapperOptions;
use sparsemap::model::{dump_to_string, load_dump, NetworkGraph};
use sparsemap::sparse::partition::SparseLayer;
use sparsemap::sparse::prune::synthetic_pruned_layer;
use sparsemap::util::rng::Pcg64;

/// The acceptance network: four pruned layers, the third in the
/// wide_k128 class (k = 128 tiles at ~0.92 sparsity — the shape the
/// mapper's wide operating point exists for).
fn acceptance_layers() -> Vec<SparseLayer> {
    vec![
        synthetic_pruned_layer("net_conv1", 6, 8, 0.50, 301).unwrap(),
        synthetic_pruned_layer("net_conv2", 8, 12, 0.60, 302).unwrap(),
        synthetic_pruned_layer("net_wide", 12, 128, 0.92, 303).unwrap(),
        synthetic_pruned_layer("net_head", 128, 8, 0.90, 304).unwrap(),
    ]
}

/// A cheaper all-small-tile network for the topology matrix.
fn small_layers() -> Vec<SparseLayer> {
    vec![
        synthetic_pruned_layer("sm1", 6, 8, 0.50, 311).unwrap(),
        synthetic_pruned_layer("sm2", 8, 10, 0.60, 312).unwrap(),
        synthetic_pruned_layer("sm3", 10, 4, 0.50, 313).unwrap(),
    ]
}

/// Serving config at the wide operating point (the k = 128 tile needs
/// its II slack), worker-pool sized for a 16-tile stage.
fn net_cfg() -> SparsemapConfig {
    let wide = MapperOptions::wide();
    let mut cfg = SparsemapConfig::default();
    cfg.workers = 2;
    cfg.queue_depth = 32;
    cfg.parallelism = 1;
    cfg.ii_slack = wide.ii_slack;
    cfg.mis_iterations = wide.mis_iterations;
    cfg
}

fn input_for(width: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seeded(seed);
    (0..width).map(|_| rng.next_normal() as f32).collect()
}

fn bits(r: &NetworkResult) -> Vec<u32> {
    r.outputs.iter().map(|v| v.to_bits()).collect()
}

/// The reference chain: each partitioned tile served SOLO through the
/// plain session API with the pipeline's exact gather/scatter (live
/// channels in, scatter-sum at the tile's kernel offset, partition
/// order). Serving outputs are a pure function of the mapping — window
/// composition, shard count and backend never move bits — so the
/// pipeline must reproduce this chain exactly.
fn serve_reference_chain(coord: &Coordinator, net: &NetworkGraph, x: &[f32]) -> Vec<f32> {
    let mut cur = x.to_vec();
    for nl in &net.layers {
        let mut acc = vec![0f32; nl.layer.k_total];
        for lb in &nl.blocks {
            let live = SparseLayer::live_channels(&lb.block.name);
            let xs = vec![live.iter().map(|&ch| cur[ch]).collect::<Vec<f32>>()];
            // Same shape as the pipeline's stage driver: the throwaway
            // session drops before the wait, sealing any window the
            // request joined.
            let ticket = {
                let mut session = coord.session();
                session.enqueue(Arc::new(lb.block.clone()), xs)
            };
            let res = ticket.wait().expect("reference tile request ok");
            let y = res.outputs.first().cloned().unwrap_or_default();
            for (bk, &v) in y.iter().enumerate() {
                acc[lb.kr_offset + bk] += v;
            }
        }
        cur = acc;
    }
    cur
}

#[test]
fn pipeline_serves_the_acceptance_network_end_to_end() {
    // Ingest path: the network travels as a dump (bit-identical round
    // trip) and `cli ingest` accepts the file with exit code 0.
    let layers = acceptance_layers();
    let text = dump_to_string("acceptance_net", &layers);
    let path = std::env::temp_dir()
        .join(format!("sparsemap-acceptance-net-{}.dump", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    std::fs::write(&path, &text).unwrap();
    let code =
        sparsemap::cli::run(vec!["ingest".to_string(), "--dump".to_string(), path_s.clone()]);
    assert_eq!(code, 0, "cli ingest must accept the acceptance dump");
    let dump = load_dump(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);

    let net = NetworkGraph::from_layers(&dump.name, dump.layers).unwrap();
    assert!(net.layers.len() >= 4, "acceptance network is >= 4 layers");
    assert!(
        net.layers.iter().any(|nl| nl.blocks.iter().any(|lb| lb.block.k >= 96)),
        "one layer must tile into the wide_k128 class"
    );

    let cfg = net_cfg();
    let coord = Coordinator::with_shard_count(&cfg, 2);
    let serving = coord.register_network(net.clone()).unwrap();
    assert_eq!(serving.name, "acceptance_net");
    assert_eq!(coord.network_names(), vec!["acceptance_net".to_string()]);
    assert_eq!(serving.block_count(), net.block_count());

    let x = input_for(net.input_width(), 41);
    let session = coord.session();
    let res = session
        .enqueue_network("acceptance_net", &x)
        .unwrap()
        .wait()
        .expect("pipeline request ok");

    // Shape + per-layer attribution.
    assert_eq!(res.outputs.len(), net.output_width());
    assert_eq!(res.layers.len(), net.layers.len(), "one attribution row per layer");
    let mut total_cops = 0usize;
    for (lm, nl) in res.layers.iter().zip(&net.layers) {
        assert_eq!(lm.layer, nl.layer.name);
        assert_eq!(lm.blocks, nl.blocks.len());
        assert!(lm.cycles > 0, "{}: zero cycles attributed", lm.layer);
        assert!(lm.latency_ns > 0, "{}: zero latency attributed", lm.layer);
        total_cops += lm.cops + lm.mcids;
    }
    assert!(total_cops > 0, "COP/MCID attribution must surface the mappings' counts");
    assert_eq!(
        res.cycles,
        res.layers.iter().map(|l| l.cycles).sum::<u64>(),
        "network cycles are the per-layer sum"
    );

    // Bit-identity against the solo-served reference chain, on a fresh
    // coordinator (same config, nothing registered): mapping and
    // simulation are deterministic, so the tiles serve identically.
    let ref_coord = Coordinator::with_shard_count(&cfg, 1);
    let reference = serve_reference_chain(&ref_coord, &net, &x);
    let got: Vec<u32> = res.outputs.iter().map(|v| v.to_bits()).collect();
    let want: Vec<u32> = reference.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "pipeline output != solo-served reference chain");

    // Approximate agreement with the dense forward chain (per-mapping
    // accumulation order differs, so this is relative-tolerance, not
    // bit-exact).
    let dense = net.forward(&x);
    for (i, (a, b)) in res.outputs.iter().zip(&dense).enumerate() {
        let tol = 1e-3 * (1.0 + b.abs());
        assert!((a - b).abs() <= tol, "output {i}: pipeline {a} vs dense {b}");
    }

    // The serving counters saw the pipeline.
    let m = coord.metrics.snapshot();
    assert_eq!(m.networks_served, 1);
    assert_eq!(m.network_stages, net.layers.len() as u64);
}

#[test]
fn pipeline_output_is_bit_identical_across_shards_and_lanes() {
    let layers = small_layers();
    let net = NetworkGraph::from_layers("matrix_net", layers).unwrap();
    let x = input_for(net.input_width(), 57);

    let run = |shards: usize, lanes: usize| -> (Vec<u32>, u64) {
        let mut cfg = net_cfg();
        cfg.sim_lanes = lanes;
        let coord = Coordinator::with_shard_count(&cfg, shards);
        let serving = coord.register_network(net.clone()).unwrap();
        let session = coord.session();
        let res = session
            .enqueue_network(&serving.name, &x)
            .unwrap()
            .wait()
            .expect("matrix pipeline ok");
        (bits(&res), res.cycles)
    };

    let reference = run(1, 1);
    for (shards, lanes) in [(1usize, 4usize), (2, 1), (2, 4)] {
        let got = run(shards, lanes);
        assert_eq!(
            got, reference,
            "pipeline output diverged at shards={shards} lanes={lanes}"
        );
    }
}

#[test]
fn repeated_pipeline_requests_are_deterministic_and_cached() {
    let net = NetworkGraph::from_layers("repeat_net", small_layers()).unwrap();
    let cfg = net_cfg();
    let coord = Coordinator::new(&cfg);
    let serving = coord.register_network(net.clone()).unwrap();
    let session = coord.session();
    let x = input_for(net.input_width(), 9);

    let first = session
        .enqueue_network(&serving.name, &x)
        .unwrap()
        .wait()
        .expect("first pass ok");
    let misses_after_first = coord.metrics.snapshot().cache_misses;
    let second = session
        .enqueue_network(&serving.name, &x)
        .unwrap()
        .wait()
        .expect("second pass ok");
    assert_eq!(bits(&first), bits(&second), "same input → same bits");
    assert_eq!(first.cycles, second.cycles, "cycle attribution is deterministic");
    assert_eq!(
        coord.metrics.snapshot().cache_misses,
        misses_after_first,
        "the second pass serves entirely from the mapping cache"
    );
    assert_eq!(coord.metrics.snapshot().networks_served, 2);
}

#[test]
fn enqueue_network_validates_name_and_input_width() {
    let cfg = net_cfg();
    let coord = Coordinator::new(&cfg);
    let session = coord.session();
    assert!(
        session.enqueue_network("nope", &[0.0]).is_err(),
        "unregistered network name must error"
    );
    let net = NetworkGraph::from_layers("vnet", small_layers()).unwrap();
    let width = net.input_width();
    coord.register_network(net).unwrap();
    let err = session.enqueue_network("vnet", &vec![0.0; width + 1]).unwrap_err();
    assert!(err.to_string().contains("expects"), "{err}");
}

#[test]
fn register_network_is_idempotent_by_name() {
    let cfg = net_cfg();
    let coord = Coordinator::new(&cfg);
    let a = coord.register_network(NetworkGraph::from_layers("idem", small_layers()).unwrap());
    let a = a.unwrap();
    let b = coord.register_network(NetworkGraph::from_layers("idem", small_layers()).unwrap());
    let b = b.unwrap();
    assert!(Arc::ptr_eq(&a, &b), "second registration returns the existing serving form");
    assert_eq!(coord.network_names().len(), 1);
}
