//! Integration suite for the ticket-based serving API.
//!
//! Locks the session/ticket redesign against the retired `submit`/`collect`
//! fire-hose (which survives as deprecated shims over an internal
//! session): bit-identical results on mixed fused/unfused traffic across
//! parallelism levels and batching settings, out-of-order `wait`
//! correctness, sticky-failure fast-fail through tickets, structured
//! per-request errors, and deterministic batching-window formation under a
//! fixed enqueue order.

use std::sync::Arc;

use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, InferRequest, ServeError, Ticket};
use sparsemap::error::Error;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sparse::fuse::FusedBundle;
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
    Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
}

fn tiny_members() -> Vec<Arc<SparseBlock>> {
    vec![
        tiny("f1", 2, 2, vec![true, false, true, true]),
        tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
        tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
    ]
}

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

/// The mixed fused/unfused traffic pattern every equivalence run uses:
/// `(block, stream)` pairs in a fixed enqueue order — two waves over the
/// bundle members with an unregistered solo block in between.
fn traffic() -> Vec<(Arc<SparseBlock>, Vec<Vec<f32>>)> {
    let members = tiny_members();
    let solo = tiny("solo", 3, 3, vec![true, true, false, false, true, true, true, false, true]);
    let mut out = Vec::new();
    let mut seed = 0u64;
    for wave in 0..2 {
        for b in &members {
            out.push((Arc::clone(b), stream_for(b, 3 + wave, seed)));
            seed += 1;
        }
        out.push((Arc::clone(&solo), stream_for(&solo, 4, seed)));
        seed += 1;
    }
    out
}

fn cfg_with(workers: usize, parallelism: usize, window: usize) -> SparsemapConfig {
    let mut cfg = SparsemapConfig::default();
    cfg.workers = workers;
    cfg.queue_depth = 8;
    cfg.parallelism = parallelism;
    cfg.mis_iterations = 20_000;
    cfg.batch_window_requests = window;
    cfg
}

fn registered_coordinator(cfg: &SparsemapConfig) -> Coordinator {
    let coord = Coordinator::new(cfg);
    coord.register_bundle(Arc::new(FusedBundle::new(tiny_members()).unwrap()));
    coord
}

/// Serve `traffic()` through the session API; outputs in enqueue order.
fn run_session(cfg: &SparsemapConfig) -> Vec<Vec<Vec<f32>>> {
    let coord = registered_coordinator(cfg);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = traffic()
        .into_iter()
        .map(|(block, xs)| session.enqueue(block, xs))
        .collect();
    session.flush();
    tickets
        .into_iter()
        .map(|t| t.wait().expect("session job ok").outputs)
        .collect()
}

/// Serve `traffic()` through the deprecated shims; outputs in submission
/// order (the shim collects FIFO).
#[allow(deprecated)]
fn run_legacy(cfg: &SparsemapConfig) -> Vec<Vec<Vec<f32>>> {
    let coord = registered_coordinator(cfg);
    let requests = traffic();
    let n = requests.len();
    for (id, (block, xs)) in requests.into_iter().enumerate() {
        coord.submit(InferRequest { id: id as u64, block, xs }).unwrap();
    }
    let mut results: Vec<_> = coord
        .collect(n)
        .into_iter()
        .map(|r| r.expect("legacy job ok"))
        .collect();
    results.sort_by_key(|r| r.id);
    results.into_iter().map(|r| r.outputs).collect()
}

fn assert_bitwise_eq(a: &[Vec<Vec<f32>>], b: &[Vec<Vec<f32>>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: request counts");
    for (ri, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what}: request {ri} iterations");
        for (it, (va, vb)) in ra.iter().zip(rb).enumerate() {
            for (kr, (x, y)) in va.iter().zip(vb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: request {ri} iter {it} kernel {kr}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn ticket_results_bit_identical_to_legacy_collect() {
    // The old fire-hose and the new session API must produce bit-identical
    // outputs for the same mixed fused/unfused traffic, at every
    // parallelism level and whether or not requests batch into windows.
    let base = run_session(&cfg_with(1, 1, 8));
    for (workers, parallelism) in [(1usize, 1usize), (2, 2), (3, 4)] {
        for window in [1usize, 8] {
            let cfg = cfg_with(workers, parallelism, window);
            assert_bitwise_eq(
                &run_session(&cfg),
                &base,
                &format!("session w={workers} p={parallelism} win={window}"),
            );
            assert_bitwise_eq(
                &run_legacy(&cfg),
                &base,
                &format!("legacy w={workers} p={parallelism} win={window}"),
            );
        }
    }
}

#[test]
fn out_of_order_wait_and_try_wait() {
    let cfg = cfg_with(2, 1, 8);
    let coord = Coordinator::new(&cfg);
    let mut session = coord.session();
    let blocks = tiny_members(); // unregistered here → solo serving
    let streams: Vec<Vec<Vec<f32>>> =
        blocks.iter().enumerate().map(|(i, b)| stream_for(b, 4, 50 + i as u64)).collect();
    let mut tickets: Vec<Ticket> = blocks
        .iter()
        .zip(&streams)
        .map(|(b, xs)| session.enqueue(Arc::clone(b), xs.clone()))
        .collect();

    // Poll the LAST ticket to completion first, then wait the rest in
    // reverse order — results are keyed by handle, not arrival order.
    let mut last = tickets.pop().unwrap();
    let polled = loop {
        if let Some(r) = last.try_wait() {
            break r.expect("polled job ok");
        }
        std::thread::yield_now();
    };
    // try_wait clones; wait still returns the same result.
    let waited = last.wait().expect("waited job ok");
    assert_eq!(polled.id, waited.id);
    assert_bitwise_eq(
        std::slice::from_ref(&polled.outputs),
        std::slice::from_ref(&waited.outputs),
        "try_wait vs wait",
    );

    let mut results = vec![waited];
    while let Some(t) = tickets.pop() {
        results.push(t.wait().expect("job ok"));
    }
    results.sort_by_key(|r| r.id);
    for ((block, xs), r) in blocks.iter().zip(&streams).zip(&results) {
        assert_eq!(r.block_name, block.name);
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, w) in y.iter().zip(&want) {
                assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{}: {a} vs {w}", block.name);
            }
        }
    }
}

#[test]
fn sticky_failure_fast_fails_through_tickets() {
    // Find a deterministically unmappable (block, operating point): a zero
    // SBTS budget with no II slack leaves only the greedy bind init, which
    // the denser paper blocks cannot satisfy at MII. The outcome is
    // deterministic for a fixed block/config, so calibrate once here and
    // reuse the same config in the coordinator.
    let hostile = MapperOptions {
        ii_slack: 0,
        mis_iterations: 0,
        ..MapperOptions::sparsemap()
    };
    let cgra = sparsemap::arch::StreamingCgra::paper_default();
    let failing = paper_blocks()
        .into_iter()
        .find(|nb| map_block(&nb.block, &cgra, &hostile).is_err());
    let Some(nb) = failing else {
        eprintln!("ignored: every paper block maps even with a zero SBTS budget");
        return;
    };
    let block = Arc::new(nb.block);

    let mut cfg = cfg_with(4, 1, 8);
    cfg.ii_slack = hostile.ii_slack;
    cfg.mis_iterations = hostile.mis_iterations;
    let coord = Coordinator::new(&cfg);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = (0..6u64)
        .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 2, seed)))
        .collect();
    session.drain();
    for t in tickets {
        match t.wait() {
            Err(ServeError::MappingFailed(msg)) => {
                assert!(!msg.is_empty(), "mapping failure carries the mapper's reason");
            }
            other => panic!("expected MappingFailed, got {other:?}"),
        }
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 6);
    assert_eq!(m.cache_misses, 0, "failed builds never count as landed mappings");
}

#[test]
fn malformed_request_inputs_fail_as_sim_errors() {
    // A request whose input vectors do not match the block's channel count
    // is a per-request failure (structured, not stringly): the mapping is
    // fine, the simulation pass rejects the stream.
    let cfg = cfg_with(2, 1, 8);
    let coord = Coordinator::new(&cfg);
    let mut session = coord.session();
    let block = tiny("badxs", 2, 2, vec![true, false, true, true]);
    let bad_xs = vec![vec![0.5f32; 5]]; // 5 values for 2 channels
    let t = session.enqueue(Arc::clone(&block), bad_xs);
    match t.wait() {
        Err(ServeError::Sim(msg)) => {
            assert!(msg.contains("input vector"), "{msg}");
        }
        other => panic!("expected Sim error, got {other:?}"),
    }
    // The mapping itself landed and keeps serving well-formed requests.
    let ok = session.enqueue(Arc::clone(&block), stream_for(&block, 3, 9));
    assert!(ok.wait().is_ok());
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 1);
    assert_eq!(m.cache_misses, 1);
}

#[test]
fn windows_form_deterministically_under_fixed_enqueue_order() {
    // Window formation is a pure function of enqueue order and the two
    // knobs — identical across runs and worker counts.
    let count_windows = |workers: usize, window: usize, n: usize| -> u64 {
        let cfg = cfg_with(workers, 1, window);
        let coord = registered_coordinator(&cfg);
        let members = tiny_members();
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..n)
            .map(|i| {
                let b = &members[i % members.len()];
                session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
            })
            .collect();
        session.drain();
        for t in tickets {
            t.wait().expect("windowed job ok");
        }
        coord.metrics.snapshot().windows
    };
    for workers in [1usize, 2, 4] {
        assert_eq!(count_windows(workers, 4, 10), 3, "10 requests / window 4 → 3 windows");
        assert_eq!(count_windows(workers, 1, 5), 5, "window 1 disables aggregation");
    }
}

#[test]
#[allow(deprecated)]
fn legacy_collect_reports_missing_results_as_runtime_errors() {
    // The deprecated shim's contract for over-collection: slots beyond the
    // outstanding submissions come back as the old stringly error.
    let cfg = cfg_with(1, 1, 8);
    let coord = Coordinator::new(&cfg);
    let results = coord.collect(3);
    assert_eq!(results.len(), 3);
    for r in results {
        match r {
            Err(Error::Runtime(msg)) => assert!(msg.contains("worker pool"), "{msg}"),
            other => panic!("expected Runtime error, got {other:?}"),
        }
    }
}

#[test]
fn drain_on_a_torn_down_pool_resolves_instead_of_hanging() {
    // Regression: `drain` on a session whose pool has been torn down used
    // to hang on tickets nobody would ever resolve. Every ticket must
    // resolve (served, or a structured teardown error) and drain returns.
    let cfg = cfg_with(2, 1, 8);
    let coord = registered_coordinator(&cfg);
    let members = tiny_members();
    let mut session = coord.session();
    // Member requests ride a still-open batching window at teardown time.
    let early: Vec<Ticket> = (0..3)
        .map(|i| {
            let b = &members[i % members.len()];
            session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
        })
        .collect();
    coord.shutdown();
    let late = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 9));
    session.drain(); // must return, not hang
    for t in early {
        match t.wait() {
            Ok(_) | Err(ServeError::QueueClosed) | Err(ServeError::WorkerGone) => {}
            other => panic!("expected served or torn-down, got {other:?}"),
        }
    }
    match late.wait() {
        Err(ServeError::QueueClosed) => {}
        other => panic!("post-shutdown enqueue must fail QueueClosed, got {other:?}"),
    }
}

#[test]
fn cancellation_keeps_window_formation_deterministic() {
    // Dropping an unwaited ticket withdraws its request from a
    // still-forming window — and window formation (enqueue/cancel
    // sequence in, window contents out) stays a pure function of that
    // sequence: identical windows, jobs and outputs at any worker count.
    let run = |workers: usize| -> (u64, u64, Vec<Vec<Vec<f32>>>) {
        let cfg = cfg_with(workers, 1, 3);
        let coord = registered_coordinator(&cfg);
        let members = tiny_members();
        let mut session = coord.session();
        let mut kept = Vec::new();
        for i in 0..9usize {
            let b = &members[i % members.len()];
            let t = session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64));
            if i % 3 == 1 {
                drop(t); // cancel before (or after — a no-op) the seal
            } else {
                kept.push(t);
            }
        }
        session.drain();
        let outputs = kept
            .into_iter()
            .map(|t| t.wait().expect("kept job ok").outputs)
            .collect();
        let m = coord.metrics.snapshot();
        (m.windows, m.jobs, outputs)
    };
    let (windows, jobs, base) = run(1);
    for workers in [2usize, 4] {
        let (w, j, outputs) = run(workers);
        assert_eq!(w, windows, "windows at {workers} workers");
        assert_eq!(j, jobs, "jobs at {workers} workers");
        assert_bitwise_eq(&outputs, &base, &format!("cancel pattern w={workers}"));
    }
}

#[test]
fn wait_timeout_resolves_and_result_stays_claimable() {
    let cfg = cfg_with(2, 1, 8);
    let coord = Coordinator::new(&cfg);
    let mut session = coord.session();
    let block = tiny("timed", 2, 2, vec![true, false, true, true]);
    let mut t = session.enqueue(Arc::clone(&block), stream_for(&block, 3, 1));
    // Generous bound — the tiny block serves far faster; a `None` here is
    // exactly the hang this API exists to expose.
    let r = t
        .wait_timeout(std::time::Duration::from_secs(60))
        .expect("request resolves within the bound")
        .expect("request ok");
    let again = t.wait().expect("result stays claimable after a timed wait");
    assert_eq!(r.id, again.id);
    assert_eq!(r.outputs.len(), 3);
    assert_eq!(
        again.latency_ns,
        again.queue_ns + again.service_ns,
        "end-to-end latency is the queue span plus the service span"
    );
}

#[test]
fn try_enqueue_sheds_with_overloaded_when_the_queue_backs_up() {
    // One worker, a tiny queue and a matching watermark: keep
    // try-enqueueing until admission control pushes back. Shed requests
    // cost nothing downstream; every admitted ticket still resolves.
    let mut cfg = cfg_with(1, 1, 1); // window 1: no batching aggregation
    cfg.queue_depth = 2;
    cfg.shed_watermark = 2;
    let coord = Coordinator::new(&cfg);
    let block = tiny("busy", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..200u64 {
        match session.try_enqueue(Arc::clone(&block), stream_for(&block, 64, i)) {
            Ok(t) => admitted.push(t),
            Err(ServeError::Overloaded) => shed += 1,
            Err(other) => panic!("unexpected admission error: {other:?}"),
        }
    }
    assert!(shed > 0, "200 bursts against a depth-2 queue must shed");
    for t in admitted {
        t.wait().expect("admitted request ok");
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.shed, shed, "every shed is counted, and only sheds");
}

#[test]
fn lane_windows_counter_tracks_the_vectorized_path() {
    // The counter is only meaningful when serving runs the compiled plan
    // with the configured lane width; a CI leg that pins either knob
    // suite-wide legitimately changes the answer, so skip there.
    use sparsemap::config::{SimBackend, SIM_LANES_ENV};
    if std::env::var(SimBackend::ENV).is_ok() || std::env::var(SIM_LANES_ENV).is_ok() {
        eprintln!("ignored: sim backend/lane env override active");
        return;
    }

    // 8-iteration streams: auto lane selection picks a width > 1, so both
    // the batched-window pass and the solo one-member pass must count.
    let serve = |sim_lanes: usize| -> (Vec<Vec<Vec<f32>>>, u64, u64) {
        let mut cfg = cfg_with(2, 1, 3);
        cfg.sim_lanes = sim_lanes;
        let coord = registered_coordinator(&cfg);
        let members = tiny_members();
        let solo = tiny("lanesolo", 2, 2, vec![true, true, false, true]);
        let mut session = coord.session();
        let mut tickets: Vec<Ticket> = members
            .iter()
            .enumerate()
            .map(|(i, b)| session.enqueue(Arc::clone(b), stream_for(b, 8, 300 + i as u64)))
            .collect();
        tickets.push(session.enqueue(Arc::clone(&solo), stream_for(&solo, 8, 310)));
        session.drain();
        let outputs = tickets.into_iter().map(|t| t.wait().expect("job ok").outputs).collect();
        let m = coord.metrics.snapshot();
        (outputs, m.windows, m.lane_windows)
    };

    let (vectored, windows, lane_windows) = serve(0);
    assert_eq!(windows, 1, "three member requests against a window of 3");
    assert_eq!(
        lane_windows,
        windows + 1,
        "the batched window plus the solo pass both ride the lane path"
    );
    let (scalar, _, scalar_lane_windows) = serve(1);
    assert_eq!(scalar_lane_windows, 0, "sim_lanes = 1 forces the scalar sweep");
    assert_bitwise_eq(&scalar, &vectored, "scalar vs lane serving outputs");
}

#[test]
fn dropping_a_session_never_strands_windowed_requests() {
    // An open window is sealed when its session drops (and when a member
    // ticket is waited on) — a ticket can always resolve.
    let cfg = cfg_with(2, 1, 100); // window far larger than the traffic
    let coord = registered_coordinator(&cfg);
    let members = tiny_members();
    let ticket = {
        let mut session = coord.session();
        session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 3, 1))
        // session drops here with the window still under-count
    };
    let r = ticket.wait().expect("window sealed by session drop");
    assert_eq!(r.fused_members, members.len());
    assert_eq!(coord.metrics.snapshot().windows, 1);
}
