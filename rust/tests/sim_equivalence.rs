//! Differential sim-equivalence suite: three backends, one semantics.
//!
//! The interpreter is the root oracle — compiled `ExecPlan` execution
//! must be **bit-identical** on every `BatchSimResult` field (outputs,
//! pass cycles, per-segment cycle shares, COPs/MCIDs, `pe_busy`,
//! register peaks) for every mapping the binder produces, and the
//! lane-vectorized sweep (`sim::lanes`) must match both at every lane
//! width in {1, 2, 4, 8, auto} — including windows smaller than one lane
//! chunk, where the write masks carry the tail. The suite locks that on
//! the seven paper blocks, the canonical `fused3` bundle, the `wide_k128`
//! block, ragged/padded batch windows, and ≥100 randomized blocks ×
//! window shapes; plan compilation itself must be deterministic (compile
//! twice → identical plan) and panic-free on every mappable instance.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_unit, MapOutcome, MapUnit, MapperOptions};
use sparsemap::sim::{
    execute_plan_batch, execute_plan_lanes_with, simulate_fused_batch, BatchSimResult, ExecPlan,
    ExecScratch, MemberSegment,
};
use sparsemap::sparse::gen::{fused3_bundle, paper_blocks, random_block, wide_blocks};
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

/// Field-by-field bit comparison of two batched results. `to_bits` on the
/// outputs: NaN-safe and catches signed-zero or rounding drift that `==`
/// on floats would wave through.
fn assert_bit_identical(compiled: &BatchSimResult, interp: &BatchSimResult, ctx: &str) {
    assert_eq!(compiled.cycles, interp.cycles, "{ctx}: pass cycles");
    assert_eq!(compiled.iterations, interp.iterations, "{ctx}: iterations");
    assert_eq!(compiled.pe_busy, interp.pe_busy, "{ctx}: pe_busy");
    assert_eq!(compiled.lrf_peak, interp.lrf_peak, "{ctx}: lrf_peak");
    assert_eq!(compiled.grf_peak, interp.grf_peak, "{ctx}: grf_peak");
    assert_eq!(compiled.per_member.len(), interp.per_member.len(), "{ctx}: member count");
    for (mi, (cm, im)) in compiled.per_member.iter().zip(&interp.per_member).enumerate() {
        assert_eq!(cm.cops, im.cops, "{ctx}: member {mi} COPs");
        assert_eq!(cm.mcids, im.mcids, "{ctx}: member {mi} MCIDs");
        assert_eq!(cm.segments.len(), im.segments.len(), "{ctx}: member {mi} segment count");
        for (si, (cs, is)) in cm.segments.iter().zip(&im.segments).enumerate() {
            assert_eq!(cs.cycles, is.cycles, "{ctx}: member {mi} segment {si} cycle share");
            assert_eq!(
                cs.outputs.len(),
                is.outputs.len(),
                "{ctx}: member {mi} segment {si} iteration count"
            );
            for (it, (cv, iv)) in cs.outputs.iter().zip(&is.outputs).enumerate() {
                assert_eq!(cv.len(), iv.len(), "{ctx}: member {mi} segment {si} iter {it}");
                for (kr, (a, b)) in cv.iter().zip(iv).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{ctx}: member {mi} segment {si} iter {it} kernel {kr}: \
                         compiled {a} vs interpreter {b}"
                    );
                }
            }
        }
    }
}

/// Compile the plan twice (determinism), execute the window on every
/// backend — interpreter, scalar plan, and the lane-vectorized sweep at
/// each supported width — and hold them all bit-identical. Returns the
/// (shared) result.
fn run_both(
    outcome: &MapOutcome,
    cgra: &StreamingCgra,
    blocks: &[&SparseBlock],
    batches: &[Vec<MemberSegment<'_>>],
    ctx: &str,
) -> BatchSimResult {
    let plan = ExecPlan::for_outcome(outcome, cgra)
        .unwrap_or_else(|e| panic!("{ctx}: plan compile: {e}"));
    let again = ExecPlan::for_outcome(outcome, cgra).unwrap();
    assert_eq!(plan, again, "{ctx}: plan compilation must be deterministic");
    let compiled = execute_plan_batch(&plan, blocks, batches)
        .unwrap_or_else(|e| panic!("{ctx}: compiled execution: {e}"));
    let interp =
        simulate_fused_batch(&outcome.mapping, &outcome.tags, blocks, cgra, batches)
            .unwrap_or_else(|e| panic!("{ctx}: interpreter: {e}"));
    assert_bit_identical(&compiled, &interp, ctx);
    // Lane matrix: every width against the interpreter oracle, through ONE
    // shared scratch so reuse across differently-shaped calls is
    // exercised the way a pooled worker would.
    let mut scratch = ExecScratch::new();
    for lanes in [0usize, 1, 2, 4, 8] {
        let (vectored, width) =
            execute_plan_lanes_with(&plan, blocks, batches, lanes, &mut scratch)
                .unwrap_or_else(|e| panic!("{ctx}: lanes={lanes}: {e}"));
        if lanes > 0 {
            assert_eq!(width, lanes, "{ctx}: explicit lane width must be honored");
        } else {
            assert_eq!(
                width,
                sparsemap::sim::lanes::auto_width(interp.iterations),
                "{ctx}: auto width must follow the window length"
            );
        }
        assert_bit_identical(&vectored, &interp, &format!("{ctx} [lanes={lanes}]"));
    }
    compiled
}

#[test]
fn paper_blocks_match_bitwise_on_ragged_windows() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap().with_parallelism(1);
    for (i, nb) in paper_blocks().iter().enumerate() {
        let out = map_unit(MapUnit::Single(&nb.block), &cgra, &opts)
            .unwrap_or_else(|e| panic!("{}: must map: {e}", nb.label));
        // A ragged two-segment window: 5 + 2 iterations through one
        // compiled configuration.
        let xs_a = stream_for(&nb.block, 5, 1000 + i as u64);
        let xs_b = stream_for(&nb.block, 2, 2000 + i as u64);
        let batches = vec![vec![
            MemberSegment { block: &nb.block, xs: &xs_a },
            MemberSegment { block: &nb.block, xs: &xs_b },
        ]];
        let res = run_both(&out, &cgra, &[&nb.block], &batches, nb.label);
        assert_eq!(res.iterations, 7, "{}", nb.label);
        assert_eq!(res.per_member[0].segments[0].outputs.len(), 5, "{}", nb.label);
        assert_eq!(res.per_member[0].segments[1].outputs.len(), 2, "{}", nb.label);
    }
}

#[test]
fn fused3_bundle_matches_bitwise_with_ragged_and_absent_members() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::fused().with_parallelism(1);
    let bundle = fused3_bundle();
    let out = map_unit(MapUnit::Bundle(&bundle), &cgra, &opts)
        .unwrap_or_else(|e| panic!("fused3 must map: {e}"));
    let blocks: Vec<&SparseBlock> = bundle.blocks.iter().map(|b| b.as_ref()).collect();

    // Member 0 carries two segments (4 + 3), member 1 one segment (6),
    // member 2 is absent from the window entirely — it pads with
    // zero-input iterations on both backends.
    let m0a = stream_for(blocks[0], 4, 71);
    let m0b = stream_for(blocks[0], 3, 72);
    let m1 = stream_for(blocks[1], 6, 73);
    let batches = vec![
        vec![
            MemberSegment { block: blocks[0], xs: &m0a },
            MemberSegment { block: blocks[0], xs: &m0b },
        ],
        vec![MemberSegment { block: blocks[1], xs: &m1 }],
        Vec::new(),
    ];
    let res = run_both(&out, &cgra, &blocks, &batches, "fused3 ragged");
    assert_eq!(res.iterations, 7, "lockstep length is the longest member total");
    assert!(res.per_member[2].segments.is_empty(), "absent member has no segments");

    // The all-empty degenerate window: zero iterations, still bit-identical
    // (and finite — the zero-cycle guards are unit-tested in `sim`).
    let empty = vec![Vec::new(), Vec::new(), Vec::new()];
    let res = run_both(&out, &cgra, &blocks, &empty, "fused3 empty window");
    assert_eq!(res.iterations, 0);
}

#[test]
fn wide_k128_matches_bitwise() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::wide().with_parallelism(1);
    let block = wide_blocks().remove(1);
    assert_eq!(block.name, "wide_k128");
    let out = map_unit(MapUnit::Single(&block), &cgra, &opts)
        .unwrap_or_else(|e| panic!("wide_k128 must map: {e}"));
    let xs_a = stream_for(&block, 3, 128);
    let xs_b = stream_for(&block, 2, 129);
    let batches = vec![vec![
        MemberSegment { block: &block, xs: &xs_a },
        MemberSegment { block: &block, xs: &xs_b },
    ]];
    run_both(&out, &cgra, &[&block], &batches, "wide_k128");
}

#[test]
fn randomized_blocks_and_window_shapes_match_bitwise() {
    // ≥100 randomized (block, window shape) instances. Every mappable
    // instance must compile deterministically, execute panic-free, and
    // match the interpreter bit for bit; unmappable draws are skipped
    // (mapping coverage is `tests/properties.rs`' job, not ours).
    let cgra = StreamingCgra::paper_default();
    let mut opts = MapperOptions::sparsemap().with_parallelism(1);
    opts.mis_iterations = 20_000;
    let mut rng = Pcg64::seeded(0x51EE);
    let mut covered = 0usize;
    for attempt in 0..240u64 {
        if covered >= 100 {
            break;
        }
        let c = 2 + rng.index(4);
        let k = 2 + rng.index(4);
        let p = 0.2 + 0.4 * rng.next_f64();
        let block = random_block(&format!("rnd{attempt}"), c, k, p, rng.next_u64());
        let out = match map_unit(MapUnit::Single(&block), &cgra, &opts) {
            Ok(out) => out,
            Err(_) => continue, // unmappable draw — not this suite's concern
        };
        // Window shape: 1–3 segments of 0–4 iterations each (zero-length
        // segments included — a request with an empty stream is legal).
        let n_segs = 1 + rng.index(3);
        let streams: Vec<Vec<Vec<f32>>> =
            (0..n_segs).map(|s| stream_for(&block, rng.index(5), attempt * 17 + s as u64)).collect();
        let segs: Vec<MemberSegment<'_>> = streams
            .iter()
            .map(|xs| MemberSegment { block: &block, xs: xs.as_slice() })
            .collect();
        let total: usize = streams.iter().map(Vec::len).sum();
        let batches = vec![segs];
        let res =
            run_both(&out, &cgra, &[&block], &batches, &format!("rnd{attempt} c={c} k={k}"));
        assert_eq!(res.iterations, total, "rnd{attempt}");
        covered += 1;
    }
    assert!(covered >= 100, "only {covered} randomized instances covered");
}

#[test]
fn windows_smaller_than_one_chunk_match_at_every_width() {
    // A 1-, 2- and 3-iteration window under 8 lanes leaves most of the
    // chunk as padding; the per-lane write masks must keep those ghost
    // iterations out of every output plane and closed-form counter.
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap().with_parallelism(1);
    let nb = &paper_blocks()[2];
    let out = map_unit(MapUnit::Single(&nb.block), &cgra, &opts)
        .unwrap_or_else(|e| panic!("{}: must map: {e}", nb.label));
    for n in 1..=3usize {
        let xs = stream_for(&nb.block, n, 4000 + n as u64);
        let batches = vec![vec![MemberSegment { block: &nb.block, xs: &xs }]];
        let res = run_both(&out, &cgra, &[&nb.block], &batches, &format!("tiny window n={n}"));
        assert_eq!(res.iterations, n);
        assert_eq!(res.per_member[0].segments[0].outputs.len(), n);
    }
}

#[test]
fn compiled_solo_window_matches_plain_simulate() {
    // The serving tier's solo path runs a block as a one-member window off
    // the plan; hold that against `simulate` directly, not just against
    // the batched interpreter.
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::sparsemap().with_parallelism(1);
    let nb = &paper_blocks()[0];
    let out = map_unit(MapUnit::Single(&nb.block), &cgra, &opts).unwrap();
    let xs = stream_for(&nb.block, 6, 9);
    let batches = vec![vec![MemberSegment { block: &nb.block, xs: &xs }]];
    let plan = ExecPlan::for_outcome(&out, &cgra).unwrap();
    let res = execute_plan_batch(&plan, &[&nb.block], &batches).unwrap();
    let solo = sparsemap::sim::simulate(&out.mapping, &nb.block, &cgra, &xs).unwrap();
    assert_eq!(res.cycles, solo.cycles, "pass cycles");
    let seg = &res.per_member[0].segments[0];
    assert_eq!(seg.outputs.len(), solo.outputs.len());
    for (it, (pv, sv)) in seg.outputs.iter().zip(&solo.outputs).enumerate() {
        for (kr, (a, b)) in pv.iter().zip(sv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "iter {it} kernel {kr}");
        }
    }
}
