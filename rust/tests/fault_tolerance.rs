//! Deterministic fault-injection suite for the serving tier (the
//! `failpoints` feature arms the `coordinator::*` sites; see
//! `util::failpoint`). The invariant under test everywhere: **every
//! enqueued ticket resolves** — served, or a structured `ServeError` —
//! under soft panics (caught in place), hard worker death (supervisor
//! respawn), injected mapping/simulator errors, injected delays, and
//! randomized mixtures of all of them. Bounded waits convert any hang
//! into a test failure.
#![cfg(feature = "failpoints")]

use std::sync::Arc;
use std::time::Duration;

use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, ServeError, Ticket};
use sparsemap::sparse::fuse::FusedBundle;
use sparsemap::sparse::SparseBlock;
use sparsemap::util::failpoint::{configure, FailScenario, FaultKind, Trigger};
use sparsemap::util::rng::Pcg64;

fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
    Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
}

fn tiny_members() -> Vec<Arc<SparseBlock>> {
    vec![
        tiny("f1", 2, 2, vec![true, false, true, true]),
        tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
        tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
    ]
}

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

fn cfg_with(workers: usize) -> SparsemapConfig {
    let mut cfg = SparsemapConfig::default();
    cfg.workers = workers;
    cfg.queue_depth = 8;
    cfg.parallelism = 1;
    cfg.mis_iterations = 20_000;
    cfg
}

/// Bounded wait: a ticket that does not resolve within the bound is a
/// hang — exactly the bug class this suite exists to catch.
fn must_resolve(t: &mut Ticket) -> Result<(), ServeError> {
    t.wait_timeout(Duration::from_secs(60))
        .expect("ticket must resolve under faults, not hang")
        .map(|_| ())
}

#[test]
fn soft_panic_is_caught_and_the_job_retries_in_place() {
    let _s = FailScenario::setup();
    configure("coordinator::serve", FaultKind::Panic, Trigger::Nth(1), 0);
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("soft", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = (0..4u64)
        .map(|i| session.enqueue(Arc::clone(&block), stream_for(&block, 2, i)))
        .collect();
    for mut t in tickets {
        must_resolve(&mut t).expect("retried job serves fine");
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.worker_restarts, 1, "one caught panic, one in-place restart");
    assert_eq!(m.failures, 0, "the retry absorbed the fault");
    assert_eq!(m.jobs, 4);
}

#[test]
fn hard_worker_death_respawns_and_traffic_continues() {
    let _s = FailScenario::setup();
    // Panic at pickup — OUTSIDE the per-job catch_unwind — kills the
    // worker thread itself. The doomed job's tickets resolve WorkerGone
    // as the unwind drops their completers; the supervisor respawns the
    // worker and the rest of the queue serves normally.
    configure("coordinator::worker_hard", FaultKind::Panic, Trigger::Nth(1), 0);
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("hard", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = (0..4u64)
        .map(|i| session.enqueue(Arc::clone(&block), stream_for(&block, 2, i)))
        .collect();
    let mut gone = 0;
    let mut ok = 0;
    for mut t in tickets {
        match must_resolve(&mut t) {
            Ok(()) => ok += 1,
            Err(ServeError::WorkerGone) => gone += 1,
            Err(other) => panic!("unexpected error under hard death: {other:?}"),
        }
    }
    assert_eq!(gone, 1, "exactly the job aboard the dying worker is lost");
    assert_eq!(ok, 3);
    let m = coord.metrics.snapshot();
    assert_eq!(m.worker_restarts, 1, "the supervisor respawned the dead worker");
    // The respawned pool is at full strength: fresh traffic still serves.
    let mut extra = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 9));
    must_resolve(&mut extra).expect("post-respawn request ok");
}

#[test]
fn poison_job_is_quarantined_after_the_threshold() {
    let _s = FailScenario::setup();
    // Three panics, then silence: the first request burns all three
    // strikes in its in-place retry loop and is quarantined; every later
    // request for the same identity resolves Poisoned without running.
    configure("coordinator::serve", FaultKind::Panic, Trigger::FirstN(3), 0);
    let mut cfg = cfg_with(1);
    cfg.poison_threshold = 3;
    let coord = Coordinator::new(&cfg);
    let block = tiny("toxic", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = (0..3u64)
        .map(|i| session.enqueue(Arc::clone(&block), stream_for(&block, 2, i)))
        .collect();
    for mut t in tickets {
        match must_resolve(&mut t) {
            Err(ServeError::Poisoned) => {}
            other => panic!("expected Poisoned, got {other:?}"),
        }
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.poisoned, 3);
    assert_eq!(m.failures, 3, "quarantined requests count as failures");
    assert_eq!(m.worker_restarts, 3, "three caught panics, zero thread deaths");
    // A different identity is untouched by the quarantine.
    let clean = tiny("clean", 2, 2, vec![true, true, false, true]);
    let mut t = session.enqueue(Arc::clone(&clean), stream_for(&clean, 2, 9));
    must_resolve(&mut t).expect("other blocks keep serving");
}

#[test]
fn injected_mapping_error_surfaces_as_mapping_failed_then_recovers() {
    let _s = FailScenario::setup();
    configure(
        "coordinator::map",
        FaultKind::Error("injected map fault".into()),
        Trigger::Nth(1),
        0,
    );
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("maperr", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let first = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 0));
    match first.wait() {
        Err(ServeError::MappingFailed(msg)) => {
            assert!(msg.contains("injected map fault"), "{msg}");
        }
        other => panic!("expected MappingFailed, got {other:?}"),
    }
    // Default failure_ttl = 0: the failed entry detached, the next
    // requester rebuilds — and the site is exhausted, so it succeeds.
    let second = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 1));
    second.wait().expect("mapping retries clean after the fault");
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 1);
    assert_eq!(m.cache_misses, 1, "only the landed mapping counts as a miss");
}

#[test]
fn failure_ttl_fast_fails_then_retries_the_build() {
    let _s = FailScenario::setup();
    configure(
        "coordinator::map",
        FaultKind::Error("transient map fault".into()),
        Trigger::Nth(1),
        0,
    );
    let mut cfg = cfg_with(1);
    cfg.failure_ttl = 3;
    let coord = Coordinator::new(&cfg);
    let block = tiny("ttl", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    // Request 1 fails the build; requests 2 and 3 fast-fail on the
    // resident Failed entry; request 4 rebuilds (site exhausted) and 5
    // hits the rebuilt mapping. Single worker → strict request order.
    let tickets: Vec<Ticket> = (0..5u64)
        .map(|i| session.enqueue(Arc::clone(&block), stream_for(&block, 2, i)))
        .collect();
    let outcomes: Vec<Result<(), ServeError>> =
        tickets.into_iter().map(|mut t| must_resolve(&mut t)).collect();
    match &outcomes[0] {
        Err(ServeError::MappingFailed(msg)) => {
            assert!(msg.contains("transient map fault"), "{msg}");
        }
        other => panic!("expected the builder's MappingFailed, got {other:?}"),
    }
    for (i, o) in outcomes[1..3].iter().enumerate() {
        match o {
            Err(ServeError::MappingFailed(msg)) => assert!(
                msg.contains("concurrent request"),
                "request {}: fast-fail carries the sticky reason, got {msg}",
                i + 1
            ),
            other => panic!("expected fast-fail, got {other:?}"),
        }
    }
    outcomes[3].as_ref().expect("post-TTL request rebuilds");
    outcomes[4].as_ref().expect("rebuilt mapping serves hits");
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 3);
    assert_eq!(m.cache_misses, 1);
    assert_eq!(m.cache_hits, 1);
}

#[test]
fn injected_sim_error_fails_only_its_request() {
    let _s = FailScenario::setup();
    configure(
        "coordinator::sim",
        FaultKind::Error("injected sim fault".into()),
        Trigger::Nth(1),
        0,
    );
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("simerr", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let first = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 0));
    let second = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 1));
    match first.wait() {
        Err(ServeError::Sim(msg)) => assert!(msg.contains("injected sim fault"), "{msg}"),
        other => panic!("expected Sim, got {other:?}"),
    }
    second.wait().expect("the mapping survived; only the faulted pass failed");
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 1);
    assert_eq!(m.cache_misses, 1, "the mapping landed once and stayed cached");
    assert_eq!(m.cache_hits, 1);
}

#[test]
fn injected_plan_compile_failure_falls_back_to_interpreter() {
    let _s = FailScenario::setup();
    // The plan compiler fails once, at registration of the first entry.
    // The mapping itself landed, so the entry serves off the scalar
    // interpreter instead — a loud logged fallback, never a lost ticket
    // and never a failure metric.
    configure(
        "coordinator::plan",
        FaultKind::Error("injected plan fault".into()),
        Trigger::Nth(1),
        0,
    );
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("planerr", 2, 2, vec![true, false, true, true]);
    let xs0 = stream_for(&block, 3, 0);
    let xs1 = stream_for(&block, 2, 1);
    let mut session = coord.session();
    let first = session.enqueue(Arc::clone(&block), xs0.clone());
    let second = session.enqueue(Arc::clone(&block), xs1.clone());
    let r0 = first.wait().expect("plan fallback serves the ticket");
    let r1 = second.wait().expect("the degraded entry keeps serving hits");
    let m = coord.metrics.snapshot();
    assert_eq!(m.failures, 0, "the fallback absorbed the fault");
    assert_eq!(m.cache_misses, 1, "one mapping landed (interpreter-backed)");
    assert_eq!(m.cache_hits, 1);

    // And the fallback is semantically invisible: a clean coordinator
    // (compiled backend) produces bit-identical outputs.
    sparsemap::util::failpoint::clear();
    let clean = Coordinator::new(&cfg_with(1));
    let mut cs = clean.session();
    let c0 = cs.enqueue(Arc::clone(&block), xs0).wait().expect("clean serve ok");
    let c1 = cs.enqueue(Arc::clone(&block), xs1).wait().expect("clean serve ok");
    for (deg, cln) in [(&r0, &c0), (&r1, &c1)] {
        assert_eq!(deg.outputs.len(), cln.outputs.len());
        for (a, b) in deg.outputs.iter().flatten().zip(cln.outputs.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits(), "fallback vs compiled outputs diverge");
        }
    }
}

#[test]
fn deadline_expires_while_a_slow_job_holds_the_worker() {
    let _s = FailScenario::setup();
    // A 50 ms delay on the first job holds the single worker while the
    // zero-budget requests behind it expire in the queue.
    configure("coordinator::delay", FaultKind::DelayMs(50), Trigger::Nth(1), 0);
    let coord = Coordinator::new(&cfg_with(1));
    let block = tiny("slow", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let slow = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 0));
    let rushed: Vec<Ticket> = (0..3u64)
        .map(|i| {
            session.enqueue_with_deadline(
                Arc::clone(&block),
                stream_for(&block, 2, 1 + i),
                Duration::ZERO,
            )
        })
        .collect();
    slow.wait().expect("the slow request itself serves fine");
    for mut t in rushed {
        match must_resolve(&mut t) {
            Err(ServeError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.deadline_expired, 3);
    assert_eq!(m.failures, 0, "deadline sheds are policy, not faults");
}

#[test]
fn restart_budget_exhaustion_still_resolves_every_ticket() {
    let _s = FailScenario::setup();
    // Every pickup kills the worker: budget 1 buys one respawn, then the
    // pool is gone — and the supervisor's drain keeps resolving queued
    // and late tickets until the coordinator closes the queue.
    configure("coordinator::worker_hard", FaultKind::Panic, Trigger::Always, 0);
    let mut cfg = cfg_with(1);
    cfg.restart_budget = 1;
    let coord = Coordinator::new(&cfg);
    let block = tiny("doomed", 2, 2, vec![true, false, true, true]);
    let mut session = coord.session();
    let tickets: Vec<Ticket> = (0..6u64)
        .map(|i| session.enqueue(Arc::clone(&block), stream_for(&block, 2, i)))
        .collect();
    for mut t in tickets {
        match must_resolve(&mut t) {
            Err(ServeError::WorkerGone) => {}
            other => panic!("expected WorkerGone from the dead pool, got {other:?}"),
        }
    }
    assert_eq!(coord.metrics.snapshot().worker_restarts, 1, "budget bought one respawn");
    // The queue is still open: a late enqueue resolves through the
    // supervisor's drain instead of hanging.
    let mut late = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 9));
    match must_resolve(&mut late) {
        Err(ServeError::WorkerGone) => {}
        other => panic!("expected WorkerGone after pool death, got {other:?}"),
    }
}

#[test]
fn randomized_soft_fault_schedules_resolve_every_ticket() {
    // Probabilistic mixtures of every soft fault (caught panics, mapping
    // and simulator errors, delays), replayed deterministically from each
    // seed, over parallelism × batching. Soft faults never kill threads,
    // so after the storm the pool must still serve clean traffic.
    for seed in [1u64, 2, 3] {
        for (workers, window) in [(1usize, 1usize), (2, 3)] {
            let _s = FailScenario::setup();
            configure("coordinator::serve", FaultKind::Panic, Trigger::Prob(0.2), seed);
            configure(
                "coordinator::map",
                FaultKind::Error("storm map fault".into()),
                Trigger::Prob(0.2),
                seed ^ 0xa5a5,
            );
            configure(
                "coordinator::sim",
                FaultKind::Error("storm sim fault".into()),
                Trigger::Prob(0.2),
                seed ^ 0x5a5a,
            );
            configure("coordinator::delay", FaultKind::DelayMs(1), Trigger::Prob(0.5), seed);
            let mut cfg = cfg_with(workers);
            cfg.batch_window_requests = window;
            let coord = Coordinator::new(&cfg);
            let members = tiny_members();
            coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
            let solo = tiny("storm", 3, 3, vec![true, true, false, false, true, true, true, false, true]);
            let mut session = coord.session();
            let mut tickets = Vec::new();
            for i in 0..12u64 {
                let b = if i % 4 == 3 { &solo } else { &members[(i % 4) as usize] };
                tickets.push(session.enqueue(Arc::clone(b), stream_for(b, 2, i)));
            }
            // Seal open windows WITHOUT waiting (`drain` would block on
            // resolution and hide a hang from the bounded waits below).
            session.flush();
            for (i, mut t) in tickets.into_iter().enumerate() {
                // Any structured outcome is fine; a hang is the bug.
                let _ = t
                    .wait_timeout(Duration::from_secs(60))
                    .unwrap_or_else(|| panic!("seed {seed} w={workers} ticket {i} hung"));
            }
            // Disarm and prove the pool survived the whole schedule.
            sparsemap::util::failpoint::clear();
            let fresh = tiny("after", 2, 2, vec![true, true, true, false]);
            let mut t = session.enqueue(Arc::clone(&fresh), stream_for(&fresh, 2, 99));
            must_resolve(&mut t).expect("pool serves clean traffic after the storm");
        }
    }
}

#[test]
fn unarmed_sites_leave_serving_deterministic() {
    // With the feature compiled in but no site armed, serving is the
    // plain fault-free path: two identical runs produce bit-identical
    // outputs (the fault-free ≡ default equivalence the feature promises,
    // observable inside one binary).
    let run = || -> Vec<Vec<Vec<f32>>> {
        let _s = FailScenario::setup(); // clean registry, serialized
        let coord = Coordinator::new(&cfg_with(2));
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..6u64)
            .map(|i| {
                let b = &members[(i % 3) as usize];
                session.enqueue(Arc::clone(b), stream_for(b, 3, i))
            })
            .collect();
        session.flush();
        tickets.into_iter().map(|t| t.wait().expect("clean run ok").outputs).collect()
    };
    let a = run();
    let b = run();
    for (ra, rb) in a.iter().zip(&b) {
        for (va, vb) in ra.iter().zip(rb) {
            for (x, y) in va.iter().zip(vb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
