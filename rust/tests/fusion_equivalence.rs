//! Fused-vs-solo differential suite for multi-block fusion.
//!
//! The fusion pipeline composes *solo* member schedules by per-member
//! modulo-slot time shifts (see `mapper::map_unit`), so inside a bundle
//! every block must carry exactly the COPs/MCIDs — and produce exactly the
//! simulated values — of its solo schedule at the bundle's winning
//! `(II, retry)`. This suite locks that property on the canonical bundle
//! of three small paper blocks and on randomized small-block bundles, and
//! drives mixed fused/unfused traffic through the coordinator at several
//! parallelism settings to pin end-to-end determinism.

use std::sync::Arc;

use sparsemap::arch::StreamingCgra;
use sparsemap::bind::bind;
use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, Ticket};
use sparsemap::dfg::analysis::AssociationMatrix;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::mapper::{map_bundle, map_unit, MapUnit, MapperOptions};
use sparsemap::sched::sparsemap::schedule_at_perturbed;
use sparsemap::sim::{simulate, simulate_fused};
use sparsemap::sparse::fuse::{plan_bundles, FusedBundle, FusionOptions};
use sparsemap::sparse::gen::{fused3_bundle, paper_blocks, random_block};
use sparsemap::sparse::SparseBlock;
use sparsemap::util::rng::Pcg64;

/// The canonical bundle (block1/2/4 — `sparse::gen::fused3_bundle`), also
/// pinned by `golden_mappings` and the `fused3/*` bench rows.
fn canonical_bundle() -> FusedBundle {
    let bundle = fused3_bundle();
    assert_eq!(bundle.len(), 3);
    bundle
}

fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}

#[test]
fn canonical_bundle_maps_deterministically_at_any_width() {
    let cgra = StreamingCgra::paper_default();
    let bundle = canonical_bundle();
    let seq = map_bundle(&bundle, &cgra, &MapperOptions::fused().with_parallelism(1))
        .unwrap_or_else(|e| panic!("canonical bundle must map: {e}"));
    seq.mapping.verify(&cgra).unwrap();
    assert_eq!(seq.tags.members(), 3);
    assert!(seq.mapping.ii >= bundle.mii(&cgra), "shared II covers the combined MII");
    for width in [2usize, 4] {
        let par = map_unit(
            MapUnit::Bundle(&bundle),
            &cgra,
            &MapperOptions::fused().with_parallelism(width),
        )
        .unwrap();
        assert_eq!(seq.mapping.ii, par.mapping.ii, "width {width}");
        assert_eq!(seq.mapping.placements, par.mapping.placements, "width {width}");
        assert_eq!(seq.attempts, par.attempts, "width {width}");
        assert_eq!(seq.tags, par.tags, "width {width}");
    }
}

#[test]
fn fused_member_schedules_are_solo_schedules_shifted() {
    // Each member's COPs/MCIDs inside the bundle must be byte-identical to
    // its solo schedule at the bundle's winning (II, retry), and the
    // member's time vector must be that solo schedule's shifted by a
    // constant.
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::fused();
    let bundle = canonical_bundle();
    let out = map_bundle(&bundle, &cgra, &opts).unwrap();
    let (ii, retry) = out.winning_attempt();
    let stats = out.per_block_stats();
    assert_eq!(stats.len(), 3);

    for (bi, member) in bundle.blocks.iter().enumerate() {
        let (g, _) = build_sdfg(member);
        let am = AssociationMatrix::build(&g);
        let solo = schedule_at_perturbed(&g, &cgra, opts.techniques, ii, retry, &am)
            .unwrap_or_else(|e| panic!("{}: solo schedule at winning attempt: {e}", member.name));
        assert_eq!(stats[bi].cops, solo.cops(), "{}: COPs", member.name);
        assert_eq!(stats[bi].mcids, solo.mcids().len(), "{}: MCIDs", member.name);

        let range = out.tags.range_of(bi);
        let fused_t = &out.mapping.s.t[range];
        assert_eq!(fused_t.len(), solo.t.len(), "{}: node counts", member.name);
        let shift = fused_t[0] as i64 - solo.t[0] as i64;
        assert!(shift >= 0, "{}: shift {shift}", member.name);
        for (v, (&ft, &st)) in fused_t.iter().zip(&solo.t).enumerate() {
            assert_eq!(
                ft as i64 - st as i64,
                shift,
                "{}: node {v} not shifted by the member constant",
                member.name
            );
        }
    }
}

#[test]
fn fused_simulation_is_bitwise_identical_to_solo() {
    // Placements differ between the fused and solo binds, but values
    // depend only on graph structure + weights — and the member graphs are
    // identical (shifted), so outputs must match bit for bit.
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::fused();
    let bundle = canonical_bundle();
    let out = map_bundle(&bundle, &cgra, &opts).unwrap();
    let (ii, retry) = out.winning_attempt();

    let streams: Vec<Vec<Vec<f32>>> = bundle
        .blocks
        .iter()
        .enumerate()
        .map(|(i, b)| stream_for(b, 8, 40 + i as u64))
        .collect();
    let blocks: Vec<&SparseBlock> = bundle.blocks.iter().map(|b| b.as_ref()).collect();
    let xs: Vec<&[Vec<f32>]> = streams.iter().map(|s| s.as_slice()).collect();
    let fused = simulate_fused(&out.mapping, &out.tags, &blocks, &cgra, &xs).unwrap();
    assert_eq!(fused.iterations, 8);

    for (bi, member) in bundle.blocks.iter().enumerate() {
        let (g, _) = build_sdfg(member);
        let am = AssociationMatrix::build(&g);
        let solo_s = schedule_at_perturbed(&g, &cgra, opts.techniques, ii, retry, &am).unwrap();
        let solo_m = bind(&solo_s, &cgra, opts.mis_iterations, opts.seed ^ retry)
            .unwrap_or_else(|e| panic!("{}: solo bind at II {ii}: {e}", member.name));
        let solo = simulate(&solo_m, member, &cgra, &streams[bi]).unwrap();
        assert_eq!(solo.outputs.len(), fused.per_block[bi].outputs.len());
        for (it, (sv, fv)) in solo.outputs.iter().zip(&fused.per_block[bi].outputs).enumerate()
        {
            for (kr, (a, b)) in sv.iter().zip(fv).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: iter {it} kernel {kr}: solo {a} vs fused {b}",
                    member.name
                );
            }
        }
        // And the simulator's per-block statistics echo the schedule's.
        assert_eq!(fused.per_block[bi].cops, solo_s.cops(), "{}", member.name);
        assert_eq!(fused.per_block[bi].mcids, solo_s.mcids().len(), "{}", member.name);
    }
}

#[test]
fn randomized_small_block_bundles_map_and_simulate() {
    let cgra = StreamingCgra::paper_default();
    let opts = MapperOptions::fused();
    let mut rng = Pcg64::seeded(0xF05E);
    let mut fused_bundles = 0usize;
    for round in 0..5u64 {
        let blocks: Vec<Arc<SparseBlock>> = (0..4 + rng.index(3))
            .map(|i| {
                let c = 2 + rng.index(4);
                let k = 2 + rng.index(4);
                let p = 0.3 + 0.4 * rng.next_f64();
                Arc::new(random_block(&format!("rb{round}_{i}"), c, k, p, rng.next_u64()))
            })
            .collect();
        let plan =
            plan_bundles(&blocks, &cgra, &FusionOptions { max_blocks: 3, max_ii: 6 });
        // The plan covers every block exactly once, in input order.
        let flat: Vec<&str> =
            plan.iter().flat_map(|bu| bu.blocks.iter().map(|b| b.name.as_str())).collect();
        assert_eq!(flat, blocks.iter().map(|b| b.name.as_str()).collect::<Vec<_>>());
        for bundle in plan.iter().filter(|bu| bu.len() > 1) {
            fused_bundles += 1;
            let out = map_bundle(bundle, &cgra, &opts)
                .unwrap_or_else(|e| panic!("{}: random bundle must map: {e}", bundle.name));
            out.mapping.verify(&cgra).unwrap();
            // Per-member outputs match the reference forward.
            let streams: Vec<Vec<Vec<f32>>> = bundle
                .blocks
                .iter()
                .enumerate()
                .map(|(i, b)| stream_for(b, 4, round * 31 + i as u64))
                .collect();
            let members: Vec<&SparseBlock> = bundle.blocks.iter().map(|b| b.as_ref()).collect();
            let xs: Vec<&[Vec<f32>]> = streams.iter().map(|s| s.as_slice()).collect();
            let res = simulate_fused(&out.mapping, &out.tags, &members, &cgra, &xs)
                .unwrap_or_else(|e| panic!("{}: fused sim: {e}", bundle.name));
            for (bi, b) in members.iter().enumerate() {
                for (x, y) in streams[bi].iter().zip(&res.per_block[bi].outputs) {
                    let want = b.forward(x);
                    for (a, w) in y.iter().zip(&want) {
                        assert!(
                            (a - w).abs() < 1e-4 * (1.0 + w.abs()),
                            "{} member {bi}: {a} vs {w}",
                            bundle.name
                        );
                    }
                }
            }
        }
    }
    assert!(fused_bundles >= 5, "only {fused_bundles} fused bundles exercised");
}

#[test]
fn coordinator_serves_mixed_traffic_deterministically_at_any_parallelism() {
    // The acceptance scenario end-to-end: a registered 3-block bundle plus
    // an unfused block, served concurrently; outputs must be bit-identical
    // across coordinator/portfolio parallelism settings.
    let bundle_blocks: Vec<Arc<SparseBlock>> = canonical_bundle().blocks;
    let solo = Arc::new(paper_blocks()[2].block.clone()); // block3, unfused

    let run = |parallelism: usize, workers: usize| -> Vec<Vec<Vec<f32>>> {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = workers;
        cfg.queue_depth = 8;
        cfg.parallelism = parallelism;
        cfg.ii_slack = 3;
        let coord = Coordinator::new(&cfg);
        coord.register_bundle(Arc::new(FusedBundle::new(bundle_blocks.clone()).unwrap()));
        let mut requests: Vec<(u64, Arc<SparseBlock>)> = Vec::new();
        for (i, b) in bundle_blocks.iter().enumerate() {
            requests.push((i as u64, Arc::clone(b)));
        }
        requests.push((3, Arc::clone(&solo)));
        // A second wave over the same blocks exercises the warm cache.
        for (i, b) in bundle_blocks.iter().enumerate() {
            requests.push((4 + i as u64, Arc::clone(b)));
        }
        let mut session = coord.session();
        let tickets: Vec<Ticket> = requests
            .iter()
            .map(|(id, block)| {
                let xs = stream_for(block, 3, *id % 4);
                session.enqueue(Arc::clone(block), xs)
            })
            .collect();
        session.flush();
        tickets
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let r = t.wait().expect("mixed job ok");
                let want_members = if i == 3 { 1 } else { 3 };
                assert_eq!(r.fused_members, want_members, "request {i}");
                r.outputs
            })
            .collect()
    };

    let base = run(1, 1);
    for (parallelism, workers) in [(2, 2), (4, 3)] {
        let other = run(parallelism, workers);
        assert_eq!(base.len(), other.len());
        for (id, (a, b)) in base.iter().zip(&other).enumerate() {
            for (x, y) in a.iter().zip(b) {
                for (va, vb) in x.iter().zip(y) {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "request {id}: outputs diverge at parallelism {parallelism}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_window_is_one_pass_and_bit_identical_to_solo_serving() {
    // The acceptance scenario for fused request batching: a window of W
    // member requests runs exactly ONE fused simulation pass (windows
    // metric), and every member request's outputs are bit-identical to
    // serving the same block solo (unregistered) — values depend only on
    // graph structure and weights, and the member graphs are identical
    // shifted copies of the solo graphs.
    let members = canonical_bundle().blocks;
    let streams: Vec<Vec<Vec<f32>>> = members
        .iter()
        .enumerate()
        .map(|(i, b)| stream_for(b, 6, 70 + i as u64))
        .collect();

    let mut cfg = SparsemapConfig::default();
    cfg.workers = 2;
    cfg.queue_depth = 8;
    cfg.parallelism = 2;
    cfg.batch_window_requests = members.len();

    // Fused, batched: one window of W = 3 member requests.
    let fused_coord = Coordinator::new(&cfg);
    fused_coord
        .register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
    let mut session = fused_coord.session();
    let tickets: Vec<Ticket> = members
        .iter()
        .zip(&streams)
        .map(|(b, xs)| session.enqueue(Arc::clone(b), xs.clone()))
        .collect();
    session.drain();
    let fused_outputs: Vec<Vec<Vec<f32>>> = tickets
        .into_iter()
        .map(|t| {
            let r = t.wait().expect("batched member request ok");
            assert_eq!(r.fused_members, members.len());
            r.outputs
        })
        .collect();
    let m = fused_coord.metrics.snapshot();
    assert_eq!(m.jobs, members.len() as u64);
    assert_eq!(m.windows, 1, "W member requests must run ONE fused pass");
    assert_eq!(m.cache_misses, 1, "one shared fused mapping");

    // Solo reference: same blocks, same streams, no registration.
    let solo_coord = Coordinator::new(&cfg);
    let mut solo_session = solo_coord.session();
    let solo_tickets: Vec<Ticket> = members
        .iter()
        .zip(&streams)
        .map(|(b, xs)| solo_session.enqueue(Arc::clone(b), xs.clone()))
        .collect();
    let solo_outputs: Vec<Vec<Vec<f32>>> = solo_tickets
        .into_iter()
        .map(|t| t.wait().expect("solo request ok").outputs)
        .collect();
    assert_eq!(solo_coord.metrics.snapshot().windows, 0);

    for (bi, (fs, ss)) in fused_outputs.iter().zip(&solo_outputs).enumerate() {
        assert_eq!(fs.len(), ss.len(), "member {bi}");
        for (it, (fv, sv)) in fs.iter().zip(ss).enumerate() {
            for (kr, (a, b)) in fv.iter().zip(sv).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "member {bi} iter {it} kernel {kr}: batched {a} vs solo {b}"
                );
            }
        }
    }
}

#[test]
fn batched_windows_charge_cycles_once_per_window() {
    // The Metrics::total_cycles double-count fix, on the canonical fused3
    // bundle: W member requests served through one batching window charge
    // the resident configuration ONCE; the same traffic served
    // per-member-serially (window size 1) charges it W times.
    let members = canonical_bundle().blocks;
    let serve = |window_requests: usize| -> (u64, u64) {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 8;
        cfg.batch_window_requests = window_requests;
        let coord = Coordinator::new(&cfg);
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..2 * members.len())
            .map(|i| {
                let b = &members[i % members.len()];
                session.enqueue(Arc::clone(b), stream_for(b, 8, i as u64))
            })
            .collect();
        session.drain();
        let mut attributed = 0u64;
        for t in tickets {
            attributed += t.wait().expect("member request ok").cycles;
        }
        let m = coord.metrics.snapshot();
        assert_eq!(
            attributed, m.total_cycles,
            "per-request cycle shares must sum to the charged totals"
        );
        (m.total_cycles, m.windows)
    };
    let (batched_cycles, batched_windows) = serve(2 * members.len());
    let (serial_cycles, serial_windows) = serve(1);
    assert_eq!(batched_windows, 1);
    assert_eq!(serial_windows, 2 * members.len() as u64);
    assert!(
        batched_cycles < serial_cycles,
        "fused-batched totals ({batched_cycles}) must undercut per-member-serial \
         totals ({serial_cycles})"
    );
}
