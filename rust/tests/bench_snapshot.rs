//! Smoke-level perf snapshot: exercises the bench harness → JSON merge
//! pipeline end-to-end on a tiny budget (against a temp file, so `cargo
//! test` never dirties the worktree). The tracked `BENCH_mapper.json` at
//! the repo root is produced by `cargo bench --bench mapper_micro` /
//! `--bench serving_throughput` in release mode.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::bench::{black_box, BenchConfig, Bencher};

#[test]
fn perf_snapshot_exercises_json_pipeline() {
    let cgra = StreamingCgra::paper_default();
    let nb = &paper_blocks()[0]; // block1: the cheap representative
    let mut b = Bencher::with_config(BenchConfig {
        warmup_ns: 1_000_000,
        measure_ns: 10_000_000,
        samples: 2,
    });
    let seq = MapperOptions::sparsemap().with_parallelism(1);
    b.bench("smoke/block1/map_block_seq", || {
        black_box(map_block(&nb.block, &cgra, &seq).ok());
    });
    let par = MapperOptions::sparsemap().with_parallelism(2);
    b.bench("smoke/block1/map_block_par2", || {
        black_box(map_block(&nb.block, &cgra, &par).ok());
    });

    let path = std::env::temp_dir().join(format!(
        "sparsemap_bench_snapshot_{}.json",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);
    b.write_json(&path).expect("write snapshot json");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("smoke/block1/map_block_seq"), "{text}");
    assert!(text.contains("smoke/block1/map_block_par2"), "{text}");
    let _ = std::fs::remove_file(&path);
}
