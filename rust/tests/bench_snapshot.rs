//! Smoke-level perf snapshot: exercises the bench harness → JSON merge
//! pipeline end-to-end on a tiny budget (against a temp file, so `cargo
//! test` never dirties the worktree). The tracked `BENCH_mapper.json` at
//! the repo root is produced by `cargo bench --bench mapper_micro` /
//! `--bench serving_throughput` in release mode.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::bench::{black_box, repo_root_path, row_field, row_name, BenchConfig, Bencher};

#[test]
fn perf_snapshot_exercises_json_pipeline() {
    let cgra = StreamingCgra::paper_default();
    let nb = &paper_blocks()[0]; // block1: the cheap representative
    let mut b = Bencher::with_config(BenchConfig {
        warmup_ns: 1_000_000,
        measure_ns: 10_000_000,
        samples: 2,
    });
    let seq = MapperOptions::sparsemap().with_parallelism(1);
    b.bench("smoke/block1/map_block_seq", || {
        black_box(map_block(&nb.block, &cgra, &seq).ok());
    });
    let par = MapperOptions::sparsemap().with_parallelism(2);
    b.bench("smoke/block1/map_block_par2", || {
        black_box(map_block(&nb.block, &cgra, &par).ok());
    });

    let path = std::env::temp_dir().join(format!(
        "sparsemap_bench_snapshot_{}.json",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);
    b.write_json(&path).expect("write snapshot json");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("smoke/block1/map_block_seq"), "{text}");
    assert!(text.contains("smoke/block1/map_block_par2"), "{text}");
    let _ = std::fs::remove_file(&path);
}

/// The tracked `BENCH_mapper.json` is optional (produced by `cargo bench`
/// in a toolchain-equipped environment), but when it exists it must
/// conform to the `util::bench::write_json_merged` line format (read back
/// through the same `row_name`/`row_field` helpers the merger uses) —
/// this is what keeps the cross-PR perf trajectory parseable. When it's
/// absent the test says so explicitly instead of passing vacuously.
#[test]
fn bench_mapper_json_schema() {
    let path = repo_root_path("BENCH_mapper.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!(
            "ignored: no bench data ({path} absent — run `cargo bench --bench \
             mapper_micro` and `--bench serving_throughput` to produce it)"
        );
        return;
    };
    let trimmed = text.trim();
    assert!(
        trimmed.starts_with('[') && trimmed.ends_with(']'),
        "{path}: not a JSON array:\n{trimmed}"
    );
    let mut names = std::collections::HashSet::new();
    let mut rows = 0usize;
    for line in text.lines() {
        let t = line.trim().trim_end_matches(',');
        if t.is_empty() || t == "[" || t == "]" {
            continue;
        }
        assert!(
            t.starts_with('{') && t.ends_with('}'),
            "{path}: row is not a flat object: {t}"
        );
        let name =
            row_name(t).unwrap_or_else(|| panic!("{path}: row has no leading name field: {t}"));
        assert!(!name.is_empty(), "{path}: empty bench name: {t}");
        assert!(names.insert(name.to_string()), "{path}: duplicate bench row '{name}'");
        for key in ["ns_per_iter", "stddev_ns", "p95_ns"] {
            let v: f64 = row_field(t, key)
                .unwrap_or_else(|| panic!("{path}: row missing {key}: {t}"))
                .parse()
                .unwrap_or_else(|e| panic!("{path}: bad {key} in '{name}': {e}"));
            assert!(v.is_finite() && v >= 0.0, "{path}: {key} = {v} in '{name}'");
        }
        for key in ["samples", "iters_per_sample"] {
            let v: u64 = row_field(t, key)
                .unwrap_or_else(|| panic!("{path}: row missing {key}: {t}"))
                .parse()
                .unwrap_or_else(|e| panic!("{path}: bad {key} in '{name}': {e}"));
            assert!(v > 0, "{path}: {key} = 0 in '{name}'");
        }
        rows += 1;
    }
    assert!(rows > 0, "{path}: exists but holds no bench rows");
    // Row-set completeness: a file carrying mapper_micro rows must carry
    // that bench's wide-block and association rows too (they are written in
    // the same run — their absence means a stale or truncated merge), and
    // likewise for serving_throughput's wide scenario. Same guard PR 2
    // added for the mapper rows.
    let require = |marker: &str, wanted: &[&str]| {
        if !names.contains(marker) {
            return;
        }
        for w in wanted {
            assert!(
                names.contains(*w),
                "{path}: has '{marker}' but is missing its sibling row '{w}' — \
                 stale or malformed merge; re-run the bench that writes both"
            );
        }
    };
    require(
        "block1/map_block_seq",
        &[
            "block1/assoc_build",
            "block5/assoc_build",
            "block5/assoc_build_naive",
            "wide_k128/assoc_build",
            "wide_k128/assoc_build_naive",
            "wide_k256/assoc_build",
            "wide_k256/assoc_build_naive",
            "wide_k128/map_block_par4",
            "wide_k128/simulate_8it",
            "fused3/map_bundle_par4",
            "fused3/simulate_8it",
            "fused3/plan_compile",
        ],
    );
    // The hot-scan rows are emitted pairwise (both or neither — the bench
    // skips them only when wide_k256 has no routable schedule).
    require("wide_k256/bus_hot_scan_dense", &["wide_k256/bus_hot_scan_hash"]);
    require("wide_k256/bus_hot_scan_hash", &["wide_k256/bus_hot_scan_dense"]);
    require(
        "serving/workers=1/per_request",
        &[
            "serving/wide_k128/per_request",
            "serving/wide_k128/cold_start_request",
            "serving/fused3/per_request",
            "serving/fused3/cold_start_request",
            "serving/fused3/batched_request",
            "serving/fused3/window8",
        ],
    );
    // The robustness rows (overload shedding, deadline misses) joined
    // serving_throughput later than the rows above, so a snapshot merged
    // from an older bench run may legitimately lack them — they are NOT
    // required off the workers=1 marker. One run writes both, though, so
    // their presence is pairwise (either stale file without them, or a
    // current file with the pair).
    require("serving/fused3/shed_overload", &["serving/wide_k128/deadline_miss_rate"]);
    require("serving/wide_k128/deadline_miss_rate", &["serving/fused3/shed_overload"]);
    // The compiled-backend rows are emitted in the same serving run as
    // their interpreter siblings (one measures the plan path, the other
    // the scalar oracle on identical traffic) — require them pairwise so
    // a merge can't keep one half of a comparison.
    require("serving/fused3/window8_compiled", &["serving/fused3/window8"]);
    require("serving/fused3/window8", &["serving/fused3/window8_compiled"]);
    require("serving/wide_k128/per_request_compiled", &["serving/wide_k128/per_request"]);
    require("serving/wide_k128/per_request", &["serving/wide_k128/per_request_compiled"]);
    // The sharded rows joined serving_throughput with the sharded tier
    // (an older snapshot may predate them), but one run writes both —
    // require them pairwise.
    require("serving/sharded/window8_x2shards", &["serving/sharded/cross_session_window8"]);
    require("serving/sharded/cross_session_window8", &["serving/sharded/window8_x2shards"]);
    // Lane-vectorized rows: each `_lanes` row only means anything next to
    // its scalar-plan sibling (the pair IS the measurement), so require
    // them pairwise. The micro rows are one mapper_micro run with
    // plan_compile; the serving rows ride the same run as the compiled
    // twins. Older snapshots may predate all of them — nothing here keys
    // off the generic markers above.
    require("fused3/plan_sweep_lanes1", &["fused3/plan_sweep_lanes8", "fused3/plan_compile"]);
    require("fused3/plan_sweep_lanes8", &["fused3/plan_sweep_lanes1", "fused3/plan_compile"]);
    require("serving/fused3/window8_lanes", &["serving/fused3/window8_compiled"]);
    require("serving/fused3/window8_compiled", &["serving/fused3/window8_lanes"]);
    require("serving/wide_k128/window8_lanes", &["serving/wide_k128/per_request_compiled"]);
    // Network pipeline rows: one serving run writes both (the per_layer
    // row is the e2e passes normalized by stage count), so a merge must
    // keep the pair together.
    require("serving/network/vgg_head_e2e", &["serving/network/per_layer"]);
    require("serving/network/per_layer", &["serving/network/vgg_head_e2e"]);
    eprintln!("BENCH_mapper.json schema ok ({rows} rows)");
}
